"""Streaming target: micro-batched incremental execution with exactly-once
checkpointed recovery.

Three layers under test:

* **lowering** — ``lower_stream`` splits one lowered vec program into
  static / batch / merge / finalize segments, with named errors for the
  shapes streaming cannot support (no terminal aggregation, raw stream
  results);
* **incremental equivalence** — folding the stream table micro-batch by
  micro-batch and finalizing is element-identical to the batch interp
  oracle across the physical-plan zoo (sorted and direct group-by,
  scalar aggregates, avg desugaring, joins with static build sides,
  dict-encoded string keys, order/limit suffixes, the costed search);
* **exactly-once chaos** — ``StreamConsumer``/``stream_loop`` kill the
  consumer mid-batch, mid-snapshot, and mid-restore (the three
  ``stream.*`` injection points) and the recovered output must still be
  element-identical to the oracle: no lost batch, no double-counted
  batch.  ``REPRO_CHAOS_SEED`` selects the seeded firing pattern (CI
  sweeps two).

Plus the two serve-loop ride-alongs: backpressure pauses with bounded
un-snapshotted lag, and watermark shedding drops late batches instead of
folding them.
"""

from __future__ import annotations

import os
import re
from pathlib import Path

import numpy as np
import pytest

from repro.compiler import PlanCache, compile as cvm_compile
from repro.compiler.driver import disable_auto_replan, enable_auto_replan
from repro.core.expr import col
from repro.distributed.checkpoint import CheckpointManager
from repro.frontends.dataflow import (Context, avg_, count_, max_, sum_,
                                      _to_numpy)
from repro.launch.serve import (AdmissionQueue, MicroBatch, Request,
                                StreamConsumer, microbatches, stream_loop)
from repro.obs import tracing, write_chrome_trace
from repro.obs.feedback import FEEDBACK
from repro.robust.inject import InjectedFault, inject, registered_points

CHAOS_SEED = int(os.environ.get("REPRO_CHAOS_SEED", "0"))


@pytest.fixture(autouse=True)
def _chaos_trace(request):
    """Per-test Chrome trace when ``REPRO_CHAOS_TRACE_DIR`` is set (the CI
    chaos lane uploads these as artifacts)."""
    trace_dir = os.environ.get("REPRO_CHAOS_TRACE_DIR")
    if not trace_dir:
        yield
        return
    with tracing() as tr:
        yield
    out = Path(trace_dir)
    out.mkdir(parents=True, exist_ok=True)
    name = re.sub(r"[^\w.-]+", "_", request.node.name)
    write_chrome_trace(str(out / f"stream__{name}.json"), tr)


def make_sales_ctx() -> Context:
    rng = np.random.default_rng(7)
    n = 2048
    ctx = Context(pad_to=256)
    ctx.register("sales", {
        "region": rng.integers(0, 6, n).astype(np.int32),
        "amount": rng.gamma(2.0, 50.0, n).astype(np.float32),
        "year": rng.integers(2018, 2026, n).astype(np.int32),
    })
    return ctx


def sales_query(ctx: Context):
    return (ctx.table("sales")
            .filter(col("year") >= 2020)
            .group_by("region", max_groups=8)
            .agg(sum_("amount").as_("rev"), count_().as_("n")))


def compile_stream(ctx: Context, q, batch_rows: int = 256, **kw):
    return ctx.compile(q, target="stream", stream_table="sales",
                       batch_rows=batch_rows, cache=PlanCache(), **kw)


def assert_matches_oracle(got: dict, oracle: dict, key: str = "region") -> None:
    assert set(got) == set(oracle)
    order_got = np.argsort(np.asarray(got[key]).ravel())
    order_want = np.argsort(np.asarray(oracle[key]).ravel())
    for k in oracle:
        w = np.asarray(oracle[k]).ravel()[order_want]
        g = np.asarray(got[k]).ravel()[order_got]
        if w.dtype.kind in ("U", "S", "O"):
            assert list(g) == list(w)
        else:
            np.testing.assert_allclose(g, w, rtol=1e-4)


def sales_batches(ctx: Context, batch_rows: int = 256, **kw):
    return microbatches(ctx.tables["sales"], batch_rows, **kw)


@pytest.fixture()
def sales():
    ctx = make_sales_ctx()
    oracle = ctx.execute(sales_query(ctx), target="interp")
    return ctx, oracle


# ---------------------------------------------------------------------------
# the stream lowering split
# ---------------------------------------------------------------------------


class TestLowerStream:
    def test_grouped_split_shape(self, sales):
        ctx, _ = sales
        res = compile_stream(ctx, sales_query(ctx))
        plan = res.executable.plan
        assert plan.stream_table == "sales"
        assert plan.state_kind == "grouped"
        # the batch segment ends at the terminal aggregation...
        assert plan.batch_program.body[-1].opcode.startswith("vec.GroupAgg")
        # ...and the merge segment is the one state-combine instruction
        assert [i.opcode for i in plan.merge_program.body] == \
            ["vec.MergeGroupedState"]
        assert "stream plan" in plan.render()

    def test_scalar_split_shape(self, sales):
        ctx, _ = sales
        q = (ctx.table("sales").filter(col("year") >= 2020)
             .agg(sum_("amount").as_("total"), count_().as_("n")))
        plan = compile_stream(ctx, q).executable.plan
        assert plan.state_kind == "scalar"
        assert [i.opcode for i in plan.merge_program.body] == \
            ["vec.MergeScalarState"]

    def test_join_build_side_is_static(self):
        """The dimension-table build side runs once; only the stream probe
        side is folded per micro-batch."""
        ctx = make_sales_ctx()
        ctx.register("regions", {
            "rid": np.arange(6, dtype=np.int32),
            "weight": np.linspace(1.0, 2.0, 6).astype(np.float32),
        })
        q = (ctx.table("sales")
             .join(ctx.table("regions"), left_on="region", right_on="rid")
             .group_by("region", max_groups=8)
             .agg(sum_("amount").as_("rev")))
        plan = compile_stream(ctx, q).executable.plan
        assert plan.static_program is not None
        assert plan.batch_boundary  # build table flows in as batch args
        ops = {i.opcode for i in plan.static_program.body}
        assert "vec.ScanVec" in ops

    def test_finalize_carries_the_suffix(self, sales):
        """avg desugars to sum/count + an ExProj division — the division
        must run at finalize time, not per micro-batch."""
        ctx, _ = sales
        q = (ctx.table("sales").group_by("region", max_groups=8)
             .agg(avg_("amount").as_("mean")))
        plan = compile_stream(ctx, q).executable.plan
        assert plan.finalize_program is not None
        # the batch segment ends at the aggregation itself — the division
        # (and any decode/order/limit) lives in the finalize suffix
        assert plan.batch_program.body[-1].opcode.startswith("vec.GroupAgg")
        assert len(plan.finalize_program.body) >= 1

    def test_no_aggregation_is_an_error(self, sales):
        ctx, _ = sales
        q = ctx.table("sales").filter(col("year") >= 2020)
        with pytest.raises(ValueError, match="no aggregation over stream"):
            compile_stream(ctx, q, guard=False)

    def test_unknown_stream_table_is_an_error(self, sales):
        ctx, _ = sales
        with pytest.raises(ValueError, match="not scanned"):
            ctx.compile(sales_query(ctx), target="stream",
                        stream_table="clicks", guard=False,
                        cache=PlanCache())

    def test_driver_validates_stream_kwargs(self, sales):
        ctx, _ = sales
        q = sales_query(ctx)
        with pytest.raises(ValueError, match="pass stream_table"):
            ctx.compile(q, target="stream", cache=PlanCache())
        with pytest.raises(ValueError, match="batch_rows must be positive"):
            ctx.compile(q, target="stream", stream_table="sales",
                        batch_rows=-4, cache=PlanCache())
        with pytest.raises(ValueError, match="only apply to streaming"):
            ctx.compile(q, target="local", stream_table="sales",
                        cache=PlanCache())

    def test_batch_rows_is_part_of_the_cache_key(self, sales):
        ctx, _ = sales
        cache = PlanCache()
        q = sales_query(ctx)
        a = ctx.compile(q, target="stream", stream_table="sales",
                        batch_rows=128, cache=cache)
        b = ctx.compile(q, target="stream", stream_table="sales",
                        batch_rows=512, cache=cache)
        assert a.executable.batch_rows == 128
        assert b.executable.batch_rows == 512
        assert not b.cache_hit


# ---------------------------------------------------------------------------
# incremental == batch oracle (the exactly-once reference)
# ---------------------------------------------------------------------------


class TestIncrementalEquivalence:
    def test_batch_face_matches_interp_oracle(self, sales):
        ctx, oracle = sales
        res = compile_stream(ctx, sales_query(ctx))
        (out,) = res(ctx.sources())
        assert_matches_oracle(_to_numpy(out), oracle)

    @pytest.mark.parametrize("strategy", [{"groupby": "sorted"},
                                          {"groupby": "direct"}])
    def test_both_groupby_tiers_stream(self, sales, strategy):
        ctx, oracle = sales
        res = compile_stream(ctx, sales_query(ctx), strategy=strategy)
        (out,) = res(ctx.sources())
        assert_matches_oracle(_to_numpy(out), oracle)

    def test_incremental_face_matches_oracle(self, sales):
        ctx, oracle = sales
        res = compile_stream(ctx, sales_query(ctx))
        ex = res.executable.bind(ctx.sources())
        state = ex.init_state()
        for mb in sales_batches(ctx):
            state = ex.step(state, mb.rows)
        (out,) = ex.finalize(state)
        assert_matches_oracle(_to_numpy(out), oracle)

    def test_ragged_and_empty_batches(self, sales):
        """A short final batch and interleaved empty batches are padded to
        capacity and fold as no-ops on the invalid rows."""
        ctx, oracle = sales
        ex = compile_stream(ctx, sales_query(ctx)).executable
        ex.bind(ctx.sources())
        state = ex.init_state()
        empty = {k: v[:0] for k, v in ctx.tables["sales"].items()}
        for mb in microbatches(ctx.tables["sales"], 100):  # 2048 % 100 != 0
            state = ex.step(state, mb.rows)
            state = ex.step(state, empty)
        (out,) = ex.finalize(state)
        assert_matches_oracle(_to_numpy(out), oracle)

    def test_scalar_and_avg_aggregates(self, sales):
        ctx, _ = sales
        q = (ctx.table("sales").filter(col("year") >= 2020)
             .agg(sum_("amount").as_("total"), count_().as_("n"),
                  max_("amount").as_("hi"), avg_("amount").as_("mean")))
        oracle = ctx.execute(q, target="interp")
        got = ctx.execute(q, target="stream", stream_table="sales",
                          batch_rows=256)
        for k in oracle:
            np.testing.assert_allclose(np.asarray(got[k]).ravel(),
                                       np.asarray(oracle[k]).ravel(),
                                       rtol=1e-4)

    def test_join_against_static_build_side(self):
        ctx = make_sales_ctx()
        ctx.register("regions", {
            "rid": np.arange(6, dtype=np.int32),
            "weight": np.linspace(1.0, 2.0, 6).astype(np.float32),
        })
        q = (ctx.table("sales")
             .join(ctx.table("regions"), left_on="region", right_on="rid")
             .group_by("region", max_groups=8)
             .agg(sum_("amount").as_("rev"), count_().as_("n")))
        oracle = ctx.execute(q, target="interp")
        got = ctx.execute(q, target="stream", stream_table="sales",
                          batch_rows=256)
        assert_matches_oracle(got, oracle, key="region")

    def test_string_keys_with_order_and_limit(self):
        """Dict-encoded string keys stream; the decode + order/limit suffix
        runs at finalize time over the merged state."""
        rng = np.random.default_rng(11)
        n = 1024
        cities = np.array([f"city-{i:02d}" for i in range(12)])
        ctx = Context(pad_to=128)
        ctx.register("sales", {
            "city": cities[rng.integers(0, 12, n)],
            "amount": rng.gamma(2.0, 50.0, n).astype(np.float32),
        })
        q = (ctx.table("sales").group_by("city", max_groups=16)
             .agg(sum_("amount").as_("rev"))
             .order_by("city").limit(5))
        oracle = ctx.execute(q, target="interp")
        got = ctx.execute(q, target="stream", stream_table="sales",
                          batch_rows=128)
        for k in oracle:  # already ordered — compare positionally
            w, g = np.asarray(oracle[k]).ravel(), np.asarray(got[k]).ravel()
            if w.dtype.kind in ("U", "S", "O"):
                assert list(g) == list(w)
            else:
                np.testing.assert_allclose(g, w, rtol=1e-4)

    def test_costed_search_streams(self, sales):
        ctx, oracle = sales
        res = compile_stream(ctx, sales_query(ctx), optimize="cost")
        (out,) = res(ctx.sources())
        assert_matches_oracle(_to_numpy(out), oracle)


# ---------------------------------------------------------------------------
# the consumer protocol: sequencing, snapshots, dedup
# ---------------------------------------------------------------------------


class TestStreamConsumer:
    def test_fold_snapshot_restore_round_trip(self, sales, tmp_path):
        ctx, oracle = sales
        res = compile_stream(ctx, sales_query(ctx))
        ckpt = CheckpointManager(tmp_path, n_shards=1, keep=3)
        c = StreamConsumer(res, ctx.sources(), checkpoint=ckpt,
                           snapshot_every=2)
        for mb in sales_batches(ctx):
            c.process(mb)
        c.snapshot()
        assert c.stats.batches == 8
        assert c.stats.snapshots >= 4
        assert c.snapshot_seq == c.committed_seq == 7
        assert_matches_oracle(_to_numpy(c.results()[0]), oracle)

    def test_redelivery_is_deduped(self, sales, tmp_path):
        ctx, oracle = sales
        res = compile_stream(ctx, sales_query(ctx))
        c = StreamConsumer(res, ctx.sources(),
                           checkpoint=CheckpointManager(tmp_path))
        batches = sales_batches(ctx)
        for mb in batches:
            assert c.process(mb) is True
        for mb in batches:  # the upstream log replays everything
            assert c.process(mb) is False
        assert c.stats.deduped == len(batches)
        assert c.stats.batches == len(batches)  # folded once each
        assert_matches_oracle(_to_numpy(c.results()[0]), oracle)

    def test_process_death_new_consumer_restores_and_dedups(
            self, sales, tmp_path):
        """The crashed-consumer story: a new process restores the last
        snapshot and the upstream redelivers *everything*; dedup-by-seq
        keeps the fold exactly-once."""
        ctx, oracle = sales
        res = compile_stream(ctx, sales_query(ctx))
        ckpt = CheckpointManager(tmp_path, n_shards=1, keep=3)
        batches = sales_batches(ctx)

        first = StreamConsumer(res, ctx.sources(), checkpoint=ckpt,
                               snapshot_every=2)
        for mb in batches[:5]:     # dies after folding 5 (snapshot at seq 3)
            first.process(mb)
        assert first.snapshot_seq == 3

        second = StreamConsumer(res, ctx.sources(), checkpoint=ckpt,
                                snapshot_every=2)
        restored = second.restore()
        assert restored == 3
        for mb in batches:         # full redelivery from seq 0
            second.process(mb)
        assert second.stats.deduped == restored + 1
        assert second.stats.batches == len(batches) - restored - 1
        assert_matches_oracle(_to_numpy(second.results()[0]), oracle)

    def test_restore_without_snapshots_resets_to_initial(self, sales,
                                                         tmp_path):
        ctx, oracle = sales
        res = compile_stream(ctx, sales_query(ctx))
        c = StreamConsumer(res, ctx.sources(),
                           checkpoint=CheckpointManager(tmp_path),
                           snapshot_every=10_000)
        batches = sales_batches(ctx)
        for mb in batches[:3]:
            c.process(mb)
        assert c.restore() == -1   # nothing durable: back to the identity
        for mb in batches:
            c.process(mb)
        assert_matches_oracle(_to_numpy(c.results()[0]), oracle)

    def test_non_stream_executable_is_rejected(self, sales):
        ctx, _ = sales
        res = ctx.compile(sales_query(ctx), target="local",
                          cache=PlanCache())
        with pytest.raises(TypeError, match="stream-target executable"):
            StreamConsumer(res, ctx.sources())


# ---------------------------------------------------------------------------
# chaos: kill the consumer at every stream.* transition
# ---------------------------------------------------------------------------


class TestExactlyOnceChaos:
    def run_loop(self, ctx, tmp_path, **kw):
        res = compile_stream(ctx, sales_query(ctx))
        ckpt = CheckpointManager(tmp_path, n_shards=1, keep=3)
        c = StreamConsumer(res, ctx.sources(), checkpoint=ckpt,
                           snapshot_every=kw.pop("snapshot_every", 2))
        out = stream_loop(sales_batches(ctx), c, **kw)
        return c, _to_numpy(out[0])

    def test_stream_points_are_registered(self):
        points = registered_points()
        for name in ["stream.batch", "stream.snapshot", "stream.restore"]:
            assert name in points, sorted(points)

    def test_kill_mid_batch_recovers_exactly_once(self, sales, tmp_path):
        ctx, oracle = sales
        with inject("stream.batch", rate=1.0, times=1, seed=CHAOS_SEED):
            c, got = self.run_loop(ctx, tmp_path)
        assert c.stats.restores >= 1
        assert c.stats.replayed >= 1
        assert_matches_oracle(got, oracle)

    def test_kill_mid_snapshot_recovers_exactly_once(self, sales, tmp_path):
        ctx, oracle = sales
        with inject("stream.snapshot", rate=1.0, times=1, seed=CHAOS_SEED):
            c, got = self.run_loop(ctx, tmp_path)
        assert c.stats.failures >= 1
        assert_matches_oracle(got, oracle)
        # the final barrier still made everything durable
        assert c.snapshot_seq == c.committed_seq

    def test_failed_restore_retries_then_recovers(self, sales, tmp_path):
        ctx, oracle = sales
        with inject("stream.batch", rate=1.0, times=1, seed=CHAOS_SEED):
            with inject("stream.restore", rate=1.0, times=1,
                        seed=CHAOS_SEED):
                c, got = self.run_loop(ctx, tmp_path, max_recoveries=4)
        assert c.stats.failures >= 2   # the fold kill + the restore kill
        assert_matches_oracle(got, oracle)

    def test_seeded_random_kills_never_double_count(self, sales, tmp_path):
        """The CI sweep: whatever firing pattern the seed produces, the
        recovered output is element-identical to the batch oracle — the
        exactly-once property itself."""
        ctx, oracle = sales
        with inject("stream.batch", rate=0.3, times=3, seed=CHAOS_SEED):
            c, got = self.run_loop(ctx, tmp_path, max_recoveries=10)
        assert_matches_oracle(got, oracle)
        # rows counts folds (replays re-fold rolled-back state) — the
        # oracle equality above is what proves no *committed* double count
        assert c.stats.rows >= 2048

    def test_recovery_budget_exhaustion_reraises(self, sales, tmp_path):
        ctx, _ = sales
        with inject("stream.batch", rate=1.0, times=None, seed=CHAOS_SEED):
            with pytest.raises(InjectedFault):
                self.run_loop(ctx, tmp_path, max_recoveries=2)


# ---------------------------------------------------------------------------
# the serve loop: backpressure, watermarks, queue-wait latency
# ---------------------------------------------------------------------------


class TestStreamLoop:
    def test_backpressure_pauses_and_bounds_lag(self, sales, tmp_path):
        ctx, oracle = sales
        res = compile_stream(ctx, sales_query(ctx))
        c = StreamConsumer(res, ctx.sources(),
                           checkpoint=CheckpointManager(tmp_path),
                           snapshot_every=10_000)  # only backpressure snaps
        out = stream_loop(sales_batches(ctx), c, inflight_cap=2)
        assert c.stats.paused >= 1
        assert c.stats.snapshots >= 3   # the pauses drained the window
        assert_matches_oracle(_to_numpy(out[0]), oracle)

    def test_watermark_shedding_drops_late_batches(self, sales, tmp_path):
        """A batch whose event-time watermark lags the consumer's high
        watermark by more than ``max_lag_s`` is shed, not folded."""
        ctx, _ = sales
        res = compile_stream(ctx, sales_query(ctx))
        batches = sales_batches(ctx, watermark_col="year")
        late = MicroBatch(seq=len(batches),
                          rows=batches[0].rows, watermark=1900.0)
        c = StreamConsumer(res, ctx.sources(),
                           checkpoint=CheckpointManager(tmp_path))
        out = stream_loop(batches + [late], c, max_lag_s=5.0)
        assert c.stats.shed_watermark == 1
        assert c.stats.batches == len(batches)
        # shedding the duplicate late batch keeps the oracle answer
        oracle = ctx.execute(sales_query(ctx), target="interp")
        assert_matches_oracle(_to_numpy(out[0]), oracle)

    def test_queue_wait_is_observed(self, sales, tmp_path):
        ctx, _ = sales
        res = compile_stream(ctx, sales_query(ctx))
        c = StreamConsumer(res, ctx.sources(),
                           checkpoint=CheckpointManager(tmp_path))
        with tracing() as tr:
            stream_loop(sales_batches(ctx), c)
        assert len(tr.histograms["stream.queue_wait_s"]) == 8
        assert tr.counters["stream.batches"] == 8

    def test_offer_stamps_queue_entry_time(self):
        q = AdmissionQueue(4)
        assert q.offer(Request(rid=0, prompt=None))
        (r,) = q.take(1)
        assert r.offered_at is not None


# ---------------------------------------------------------------------------
# auto-replan: a threshold miss recompiles under observed statistics
# ---------------------------------------------------------------------------


class TestAutoReplan:
    def test_threshold_miss_swaps_the_cached_plan(self, sales):
        """Compile against a catalog whose row counts are wrong by ~100×;
        the traced run misses the threshold, and the replan hook recompiles
        under ``FEEDBACK.observed_statistics`` — the swapped plan's next
        run estimates the scan correctly."""
        ctx, _ = sales
        program = sales_query(ctx).program()
        cat = ctx.catalog()
        cat.stats = cat.stats.with_observed_rows({"sales": 16})
        cache = PlanCache()
        FEEDBACK.clear()
        enable_auto_replan(threshold=1.0)
        try:
            with tracing() as tr:
                res = cvm_compile(program, target="local", catalog=cat,
                                  cache=cache)
                res(ctx.sources())
            assert tr.counters.get("driver.replan") == 1
            assert res._replan is None          # one-shot
            with tracing():
                res(ctx.sources())
            scan = next(o for o in res.profile.observations
                        if o.opcode == "vec.ScanVec")
            assert abs(scan.rel_miss) < 0.05    # estimates now observed
        finally:
            disable_auto_replan()
            FEEDBACK.clear()

    def test_no_replan_when_disabled(self, sales):
        ctx, _ = sales
        program = sales_query(ctx).program()
        cat = ctx.catalog()
        cat.stats = cat.stats.with_observed_rows({"sales": 16})
        FEEDBACK.clear()
        with tracing() as tr:
            res = cvm_compile(program, target="local", catalog=cat,
                              cache=PlanCache())
            res(ctx.sources())
        assert "driver.replan" not in tr.counters
        assert res._replan is not None          # armed but never fired
        FEEDBACK.clear()
