"""Checkpointing and fault tolerance: the durability half of recovery.

Covers ``distributed/checkpoint.py`` (atomic save/restore round-trips,
elastic resharding, the keep-``N`` gc policy, and the corruption
quarantine + previous-step fallback that keeps one bad snapshot from
bricking recovery) and ``distributed/fault.py``'s ``StepRunner``
(restore-on-failure with bounded retries).  The streaming consumer built
on top of these is exercised end-to-end in test_stream.py.
"""

from __future__ import annotations

import json
import warnings

import numpy as np
import pytest

from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.fault import StepRunner
from repro.obs import tracing


def small_tree(scale: float = 1.0):
    return {
        "cols": {
            "region": (np.arange(8, dtype=np.int32) * int(scale)),
            "rev": np.linspace(0.0, 7.0, 8).astype(np.float32) * scale,
        },
        "valid": np.array([True] * 6 + [False] * 2),
        "count": np.float64(42.0 * scale),
    }


def assert_tree_equal(got, want):
    assert set(got) == set(want)
    np.testing.assert_array_equal(got["valid"], want["valid"])
    np.testing.assert_allclose(np.asarray(got["count"]), want["count"])
    for k in want["cols"]:
        np.testing.assert_allclose(got["cols"][k], want["cols"][k])


class TestRoundTrip:
    def test_save_restore_round_trip_with_extra(self, tmp_path):
        mgr = CheckpointManager(tmp_path, n_shards=2, keep=3)
        tree = small_tree()
        mgr.save(5, tree, extra={"seq": 5, "watermark": 2025.0})
        got, extra = mgr.restore(small_tree(0.0))
        assert_tree_equal(got, tree)
        assert extra["seq"] == 5
        assert extra["watermark"] == 2025.0

    def test_restore_specific_step(self, tmp_path):
        mgr = CheckpointManager(tmp_path, n_shards=2, keep=5)
        mgr.save(1, small_tree(1.0), extra={"seq": 1})
        mgr.save(2, small_tree(2.0), extra={"seq": 2})
        got, extra = mgr.restore(small_tree(0.0), step=1)
        assert_tree_equal(got, small_tree(1.0))
        assert extra["seq"] == 1
        with pytest.raises(FileNotFoundError):
            mgr.restore(small_tree(0.0), step=9)

    def test_steps_exclude_tmp_and_corrupt(self, tmp_path):
        mgr = CheckpointManager(tmp_path, n_shards=1, keep=5)
        mgr.save(1, small_tree())
        (tmp_path / "step_00000002.tmp").mkdir()
        (tmp_path / "step_00000003.corrupt").mkdir()
        assert mgr.steps() == [1]
        assert mgr.latest_step() == 1

    def test_restore_empty_dir_raises(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        with pytest.raises(FileNotFoundError):
            mgr.restore(small_tree(0.0))


class TestElasticReshard:
    def test_two_shard_save_restores_under_one_shard_manager(self, tmp_path):
        """A 2-pod checkpoint restores onto a 1-pod job: the shard count is
        read from the manifest, not the restoring manager."""
        tree = {"w": np.arange(64, dtype=np.float32).reshape(8, 8),
                "b": np.float32(3.0)}
        CheckpointManager(tmp_path, n_shards=2, keep=3).save(10, tree)
        step_dir = tmp_path / "step_00000010"
        assert (step_dir / "shard_0.npz").exists()
        assert (step_dir / "shard_1.npz").exists()
        got, _ = CheckpointManager(tmp_path, n_shards=1).restore(
            {"w": np.zeros((8, 8), np.float32), "b": np.float32(0.0)})
        np.testing.assert_allclose(got["w"], tree["w"])
        np.testing.assert_allclose(np.asarray(got["b"]), 3.0)

    def test_shape_mismatch_is_an_error(self, tmp_path):
        mgr = CheckpointManager(tmp_path, n_shards=2)
        mgr.save(1, {"w": np.zeros((8,), np.float32)})
        with pytest.raises(IOError):
            # strict=False still raises once every candidate is exhausted
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                mgr.restore({"w": np.zeros((9,), np.float32)})


class TestGc:
    def test_keep_policy_drops_oldest(self, tmp_path):
        mgr = CheckpointManager(tmp_path, n_shards=1, keep=3)
        for s in range(1, 6):
            mgr.save(s, small_tree(float(s)))
        assert mgr.steps() == [3, 4, 5]
        got, _ = mgr.restore(small_tree(0.0))
        assert_tree_equal(got, small_tree(5.0))

    def test_gc_spares_quarantined_dirs(self, tmp_path):
        mgr = CheckpointManager(tmp_path, n_shards=1, keep=2)
        (tmp_path / "step_00000001.corrupt").mkdir()
        for s in range(2, 6):
            mgr.save(s, small_tree())
        assert (tmp_path / "step_00000001.corrupt").exists()
        assert mgr.steps() == [4, 5]


def corrupt_shard(tmp_path, step: int) -> None:
    shard = tmp_path / f"step_{step:08d}" / "shard_0.npz"
    shard.write_bytes(shard.read_bytes()[:-7] + b"garbage")


class TestQuarantine:
    def test_corrupt_latest_falls_back_to_previous(self, tmp_path):
        mgr = CheckpointManager(tmp_path, n_shards=1, keep=5)
        mgr.save(1, small_tree(1.0), extra={"seq": 1})
        mgr.save(2, small_tree(2.0), extra={"seq": 2})
        corrupt_shard(tmp_path, 2)
        with tracing() as tr, warnings.catch_warnings():
            warnings.simplefilter("ignore")
            got, extra = mgr.restore(small_tree(0.0))
        assert_tree_equal(got, small_tree(1.0))
        assert extra["seq"] == 1
        # the bad step is quarantined, not deleted (post-mortem evidence)
        assert (tmp_path / "step_00000002.corrupt").exists()
        assert mgr.steps() == [1]
        assert tr.counters["ckpt.quarantined"] == 1

    def test_unreadable_manifest_falls_back(self, tmp_path):
        mgr = CheckpointManager(tmp_path, n_shards=1, keep=5)
        mgr.save(1, small_tree(1.0), extra={"seq": 1})
        mgr.save(2, small_tree(2.0), extra={"seq": 2})
        (tmp_path / "step_00000002" / "manifest.json").write_text("{not json")
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            got, extra = mgr.restore(small_tree(0.0))
        assert extra["seq"] == 1

    def test_strict_restore_still_raises(self, tmp_path):
        mgr = CheckpointManager(tmp_path, n_shards=1, keep=5)
        mgr.save(1, small_tree(1.0))
        mgr.save(2, small_tree(2.0))
        corrupt_shard(tmp_path, 2)
        with pytest.raises(IOError, match="hash mismatch"):
            mgr.restore(small_tree(0.0), strict=True)
        # strict never quarantines — the evidence stays in place
        assert (tmp_path / "step_00000002").exists()

    def test_every_step_corrupt_raises(self, tmp_path):
        mgr = CheckpointManager(tmp_path, n_shards=1, keep=5)
        mgr.save(1, small_tree(1.0))
        corrupt_shard(tmp_path, 1)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            with pytest.raises(IOError, match="failed to restore"):
                mgr.restore(small_tree(0.0))
        assert (tmp_path / "step_00000001.corrupt").exists()


class TestStepRunner:
    """Restore-on-failure: a mid-run crash rewinds state *and* the step
    counter to the last checkpoint, so with deterministic batches the
    final state is exactly the no-failure result."""

    @staticmethod
    def constant_batches():
        while True:
            yield np.float32(1.0)

    def test_failure_restores_and_converges(self, tmp_path):
        ckpt = CheckpointManager(tmp_path, n_shards=1, keep=3)
        calls = {"n": 0}
        failures = []

        def step_fn(acc, batch):
            calls["n"] += 1
            if calls["n"] == 8:  # crash once, after the step-6 checkpoint
                raise RuntimeError("device lost")
            return acc + batch, {"loss": float(np.sum(acc))}

        runner = StepRunner(step_fn, ckpt, ckpt_every=2, max_retries=3)
        state = runner.run((np.zeros(4, np.float32),), self.constant_batches(),
                           num_steps=10,
                           on_failure=lambda step, e: failures.append(step))
        np.testing.assert_allclose(state[0], np.full(4, 10.0))
        assert failures == [7]
        assert len(runner.history) >= 10

    def test_retry_budget_exhaustion_reraises(self, tmp_path):
        ckpt = CheckpointManager(tmp_path, n_shards=1, keep=3)

        def step_fn(acc, batch):
            raise RuntimeError("permanently poisoned")

        runner = StepRunner(step_fn, ckpt, ckpt_every=2, max_retries=2)
        with pytest.raises(RuntimeError, match="poisoned"):
            runner.run((np.zeros(4, np.float32),), self.constant_batches(),
                       num_steps=10)
