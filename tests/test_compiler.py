"""The unified compilation driver: targets, fingerprints, plan cache.

Covers the driver subsystem's contracts:
  * structural fingerprints are alpha-renaming-invariant but distinguish
    params and nested programs;
  * the same entry point compiles for every registered target and the
    results agree (spmd runs in a subprocess so it can own 8 host devices);
  * repeated compiles of the same frontend program hit the plan cache —
    including ``ElasticExecutor`` re-planning the same worker count;
  * per-pass instrumentation is recorded and rendered by ``explain()``;
  * passes that fail to reach fixpoint warn instead of truncating silently.
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.backends.multipod import ElasticExecutor
from repro.launch.hermetic import subprocess_env
from repro.compiler import (
    PlanCache,
    available_targets,
    compile as cvm_compile,
    fingerprint,
    get_target,
    program_size,
)
from repro.core import Builder, Program
from repro.core.expr import AggSpec, col
from repro.core.passes import FixpointWarning, ProgramRule
from repro.core.passes.lower_vec import Catalog
from repro.core.types import Atom, Bag, F32, TupleType
from repro.frontends.dataflow import Context, count_, sum_

ROOT = Path(__file__).resolve().parents[1]

LINEITEM = TupleType.of(
    l_quantity=F32, l_eprice=F32, l_disc=F32, l_shipdate=Atom("date"),
)

PRED = col("l_disc").between(0.05, 0.07) & (col("l_quantity") < 24.0)


def q6_program(name="q6", pred=PRED, reg_prefix="r"):
    b = Builder(name, prefix=reg_prefix)
    li = b.input("lineitem", Bag(LINEITEM))
    filtered = b.emit1("rel.Select", [li], {"pred": pred})
    projected = b.emit1(
        "rel.ExProj", [filtered],
        {"exprs": (("x", col("l_eprice") * col("l_disc")),)})
    result = b.emit1("rel.Aggr", [projected],
                     {"aggs": (AggSpec("sum", col("x"), "revenue"),)})
    return b.finish(result)


@pytest.fixture()
def sales_ctx():
    rng = np.random.default_rng(7)
    n = 2048
    ctx = Context(pad_to=256)
    ctx.register("sales", {
        "region": rng.integers(0, 6, n).astype(np.int32),
        "amount": rng.gamma(2.0, 50.0, n).astype(np.float32),
        "year": rng.integers(2018, 2026, n).astype(np.int32),
    })
    return ctx


def sales_query(ctx):
    return (ctx.table("sales")
            .filter(col("year") >= 2020)
            .group_by("region", max_groups=8)
            .agg(sum_("amount").as_("rev"), count_().as_("n")))


# ---------------------------------------------------------------------------
# fingerprints
# ---------------------------------------------------------------------------


class TestFingerprint:
    def test_equal_across_rebuilds(self):
        assert fingerprint(q6_program()) == fingerprint(q6_program())

    def test_alpha_renaming_invariant(self):
        p = q6_program()
        assert fingerprint(p) == fingerprint(p.rename_all("_x"))
        # entirely different register names from a different builder prefix
        assert fingerprint(p) == fingerprint(q6_program(reg_prefix="zz"))

    def test_program_name_irrelevant(self):
        assert fingerprint(q6_program(name="a")) == fingerprint(q6_program(name="b"))

    def test_params_distinguish(self):
        other = q6_program(pred=col("l_disc").between(0.01, 0.02)
                           & (col("l_quantity") < 24.0))
        assert fingerprint(q6_program()) != fingerprint(other)

    def test_param_order_canonical(self):
        """The same instruction with params attached in a different order
        fingerprints identically (params are a mapping, not a list)."""
        p = q6_program()
        swapped = p.with_body([
            ins if not ins.params else ins.with_params(**dict(reversed(ins.params)))
            for ins in p.body
        ])
        assert fingerprint(p) == fingerprint(swapped)

    def test_nested_programs_distinguish(self, sales_ctx):
        from repro.core.passes import Parallelize

        base = sales_query(sales_ctx).program()
        par2 = Parallelize(n=2).apply(base)
        par4 = Parallelize(n=4).apply(base)
        fps = {fingerprint(base), fingerprint(par2), fingerprint(par4)}
        assert len(fps) == 3
        # and parallelizing the same way twice agrees despite the global
        # fresh-name counters used by the rewrite
        assert fingerprint(Parallelize(n=2).apply(base)) == fingerprint(par2)

    def test_input_types_distinguish(self):
        wide = TupleType.of(l_quantity=F32, l_eprice=F32, l_disc=F32,
                            l_shipdate=Atom("date"), extra=F32)
        b = Builder("q6")
        li = b.input("lineitem", Bag(wide))
        filtered = b.emit1("rel.Select", [li], {"pred": PRED})
        projected = b.emit1(
            "rel.ExProj", [filtered],
            {"exprs": (("x", col("l_eprice") * col("l_disc")),)})
        result = b.emit1("rel.Aggr", [projected],
                         {"aggs": (AggSpec("sum", col("x"), "revenue"),)})
        assert fingerprint(q6_program()) != fingerprint(b.finish(result))


# ---------------------------------------------------------------------------
# target registry + driver
# ---------------------------------------------------------------------------


class TestTargets:
    def test_builtin_targets_registered(self):
        assert {"interp", "local", "spmd", "multipod"} <= set(available_targets())

    def test_target_declares_lowering_path(self):
        spmd = get_target("spmd")
        names = [s.name for s in spmd.lowering_path]
        assert names == ["canonicalize", "parallelize", "groupby", "join",
                         "encode", "fuse", "lower-to-mesh",
                         "grouped-recombine"]
        assert "mesh" in spmd.flavors
        # the strategy points the cost-based optimizer may search over
        assert [c.name for c in spmd.choices()] == ["groupby", "join",
                                                    "encode", "fuse",
                                                    "grouped-recombine"]

    def test_unknown_target_raises(self):
        with pytest.raises(KeyError, match="unknown compile target"):
            get_target("gpu-cluster")

    def test_non_dividing_parallel_fails_early(self, sales_ctx):
        """A worker count that doesn't divide the padded capacity errors
        with the table named, not a TypeError deep in the typing rules."""
        with pytest.raises(ValueError, match="sales"):
            sales_ctx.compile(sales_query(sales_ctx), parallel=3,
                              cache=PlanCache())

    def test_mesh_shortfall_fails_early(self, sales_ctx):
        """A mesh-backed target without enough devices errors at the driver,
        naming the shortfall, not inside jax mesh construction."""
        import jax

        need = jax.device_count() * 256
        with pytest.raises(ValueError, match="device"):
            cvm_compile(sales_query(sales_ctx).program(), target="spmd",
                        parallel=need, catalog=Catalog(
                            capacities={"sales": need * 4}), cache=False)

    def test_reregistering_target_invalidates_cache(self, sales_ctx):
        from repro.compiler import Target, register_target

        local = get_target("local")
        probe = Target(name="epoch-probe", flavors=local.flavors,
                       lowering_path=local.lowering_path,
                       make_backend=local.make_backend)
        register_target(probe)
        try:
            cache = PlanCache()
            q = sales_query(sales_ctx)
            r1 = sales_ctx.compile(q, target="epoch-probe", cache=cache)
            register_target(probe, overwrite=True)  # new lowering semantics
            r2 = sales_ctx.compile(q, target="epoch-probe", cache=cache)
            assert not r1.cache_hit
            assert not r2.cache_hit  # stale plan from the old epoch not served
        finally:
            from repro.compiler.targets import _TARGETS
            _TARGETS.pop("epoch-probe", None)


class TestDriver:
    def test_local_parallel_interp_agree(self, sales_ctx):
        q = sales_query(sales_ctx)
        seq = sales_ctx.execute(q, target="local")
        par = sales_ctx.execute(q, parallel=4, target="local")
        itp = sales_ctx.execute(q, target="interp")

        base = np.argsort(np.asarray(seq["region"]).ravel())
        for got in (par, itp):
            o = np.argsort(np.asarray(got["region"]).ravel())
            np.testing.assert_allclose(
                np.asarray(got["rev"]).ravel()[o],
                np.asarray(seq["rev"]).ravel()[base], rtol=1e-4)
            np.testing.assert_array_equal(
                np.asarray(got["n"]).ravel()[o],
                np.asarray(seq["n"]).ravel()[base])

    def test_explain_reports_instrumentation(self, sales_ctx):
        res = sales_ctx.compile(sales_query(sales_ctx), parallel=2,
                                cache=PlanCache())
        stages = [r.stage for r in res.records]
        assert "canonicalize" in stages
        assert "parallelize" in stages
        assert "lower-rel-to-vec" in stages
        assert all(r.wall_s >= 0 for r in res.records)
        text = res.explain()
        assert "parallelize" in text and "lower-rel-to-vec" in text
        assert res.fingerprint[:12] in text
        recs = res.explain_records()
        assert recs[-1]["stage"] == "backend"
        assert json.dumps(recs)  # JSON-serialisable for benchmarks

    def test_final_program_changed_flavor(self, sales_ctx):
        res = sales_ctx.compile(sales_query(sales_ctx), parallel=4,
                                cache=PlanCache())
        assert any(op.startswith("vec.") for op in res.program.opcodes())
        assert any(op.startswith("cf.") for op in res.program.opcodes())
        assert all(op != "rel.Scan" for op in res.program.opcodes())

    def test_ir_size_stays_bounded(self, sales_ctx):
        """Regression for the Parallelize fixpoint explosion: the grouped
        aggregation used to ping-pong with its own recombiner for 200
        iterations, growing the plan to ~400 instructions."""
        res = sales_ctx.compile(sales_query(sales_ctx), parallel=4,
                                cache=PlanCache())
        assert program_size(res.program) < 30


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------


class TestPlanCache:
    def test_repeated_frame_compile_hits_cache(self, sales_ctx):
        q = sales_query(sales_ctx)
        cache = PlanCache()
        r1 = sales_ctx.compile(q, parallel=2, cache=cache)
        r2 = sales_ctx.compile(q, parallel=2, cache=cache)
        assert not r1.cache_hit
        assert r2.cache_hit
        assert r2.executable is r1.executable  # the jitted plan is reused
        assert cache.stats == {"hits": 1, "misses": 1, "entries": 1,
                               "evictions": 0}

    def test_option_changes_miss(self, sales_ctx):
        q = sales_query(sales_ctx)
        cache = PlanCache()
        sales_ctx.compile(q, parallel=2, cache=cache)
        r2 = sales_ctx.compile(q, parallel=4, cache=cache)
        r3 = sales_ctx.compile(q, parallel=2, fuse=False, cache=cache)
        assert not r2.cache_hit and not r3.cache_hit
        assert cache.stats["entries"] == 3

    def test_program_changes_miss(self, sales_ctx):
        cache = PlanCache()
        q = sales_query(sales_ctx)
        sales_ctx.compile(q, cache=cache)
        r2 = sales_ctx.compile(q.filter(col("region") > 2), cache=cache)
        assert not r2.cache_hit

    def test_cache_disabled(self, sales_ctx):
        q = sales_query(sales_ctx)
        r1 = sales_ctx.compile(q, cache=False)
        r2 = sales_ctx.compile(q, cache=False)
        assert not r1.cache_hit and not r2.cache_hit

    def test_elastic_executor_replan_hits_cache(self, sales_ctx):
        q = sales_query(sales_ctx)
        cache = PlanCache()
        ex = ElasticExecutor(
            program_builder=lambda: q.program("elastic_q"),
            catalog=sales_ctx.catalog(),
            cache=cache,
        )
        r1 = ex.plan(1)
        r2 = ex.plan(1)  # elastic event back to a seen topology: cached
        assert not r1.cache_hit
        assert r2.cache_hit
        assert r2.executable is r1.executable
        (out,) = ex.run(sales_ctx.sources())
        got = out.to_numpy()
        want = sales_ctx.execute(q, target="interp")
        o1 = np.argsort(got["region"])
        o2 = np.argsort(np.asarray(want["region"]).ravel())
        np.testing.assert_allclose(got["rev"][o1],
                                   np.asarray(want["rev"]).ravel()[o2],
                                   rtol=1e-4)

    def test_lru_eviction(self, sales_ctx):
        q = sales_query(sales_ctx)
        cache = PlanCache(capacity=2)
        sales_ctx.compile(q, parallel=None, cache=cache)
        sales_ctx.compile(q, parallel=2, cache=cache)
        sales_ctx.compile(q, parallel=4, cache=cache)
        assert len(cache) == 2
        r = sales_ctx.compile(q, parallel=None, cache=cache)  # evicted → miss
        assert not r.cache_hit


# ---------------------------------------------------------------------------
# fixpoint diagnostics
# ---------------------------------------------------------------------------


class TestFixpointWarning:
    def test_nonconverging_pass_warns(self):
        class Spin(ProgramRule):
            name = "spin"
            recurse = False

            def run(self, program):
                return program.with_name(program.name + "x")

        with pytest.warns(FixpointWarning, match="spin"):
            Spin().apply(q6_program(), max_iters=5)

    def test_converging_pass_does_not_warn(self, recwarn):
        from repro.core.passes import DeadCodeElimination

        DeadCodeElimination().apply(q6_program())
        assert not [w for w in recwarn.list
                    if issubclass(w.category, FixpointWarning)]


# ---------------------------------------------------------------------------
# one entry point, every backend (spmd needs its own device fleet)
# ---------------------------------------------------------------------------

SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np

    from repro.compiler import PLAN_CACHE, compile as cvm_compile
    from repro.core.expr import col
    from repro.frontends.dataflow import Context, count_, sum_

    rng = np.random.default_rng(7)
    n = 2048
    ctx = Context(pad_to=256)
    ctx.register("sales", {
        "region": rng.integers(0, 6, n).astype(np.int32),
        "amount": rng.gamma(2.0, 50.0, n).astype(np.float32),
        "year": rng.integers(2018, 2026, n).astype(np.int32),
    })
    q = (ctx.table("sales").filter(col("year") >= 2020)
         .group_by("region", max_groups=8)
         .agg(sum_("amount").as_("rev"), count_().as_("n")))

    results = {}
    for target, parallel in [("local", None), ("spmd", 2), ("interp", None)]:
        got = ctx.execute(q, target=target, parallel=parallel)
        o = np.argsort(np.asarray(got["region"]).ravel())
        results[target] = {
            "region": np.asarray(got["region"]).ravel()[o].tolist(),
            "rev": np.asarray(got["rev"]).ravel()[o].tolist(),
            "n": np.asarray(got["n"]).ravel()[o].tolist(),
        }
    spmd_res = cvm_compile(q.program(), target="spmd", parallel=2,
                           catalog=ctx.catalog())
    results["spmd_ops"] = [op for op in spmd_res.program.opcodes()
                           if op.startswith("mesh.")]
    # scalar aggregation: the pre-aggregation must become a collective
    scalar = ctx.table("sales").filter(col("year") >= 2020).agg(
        sum_("amount").as_("rev"))
    scalar_res = cvm_compile(scalar.program(), target="spmd", parallel=2,
                             catalog=ctx.catalog())
    results["spmd_scalar_ops"] = [op for op in scalar_res.program.opcodes()
                                  if op.startswith("mesh.")]
    results["cache"] = PLAN_CACHE.stats
    print("RESULTS" + json.dumps(results))
""")


@pytest.fixture(scope="module")
def multi_target_results():
    proc = subprocess.run(
        [sys.executable, "-c", SPMD_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env=subprocess_env(ROOT),
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS")][0]
    return json.loads(line[len("RESULTS"):])


def test_one_entry_point_all_targets_identical(multi_target_results):
    r = multi_target_results
    for target in ("spmd", "interp"):
        np.testing.assert_array_equal(r[target]["region"], r["local"]["region"])
        np.testing.assert_allclose(r[target]["rev"], r["local"]["rev"],
                                   rtol=1e-4)
        np.testing.assert_array_equal(r[target]["n"], r["local"]["n"])


def test_spmd_path_lowered_to_mesh_flavor(multi_target_results):
    assert "mesh.MeshExecute" in multi_target_results["spmd_ops"]
    # the scalar pre-aggregation became a collective inside the mesh body
    assert "mesh.AllReduce" in multi_target_results["spmd_scalar_ops"]
