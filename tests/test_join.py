"""Sort-free direct-table joins + whole-pipeline join fusion (ISSUE 8).

Contracts:
  * ``rt.hash_join_direct`` (dense direct-table probe) is row-for-row
    equivalent to ``sort_by_key + merge_join_sorted`` and to the interp
    oracle — across int and composite keys, duplicate probe keys, empty
    and all-invalid inputs, and out-of-domain probe keys (which must drop,
    never alias a clipped boundary bucket);
  * duplicate build-side keys resolve to the first occurrence on both vec
    tiers (and the lowering warns that the PK-FK assumption is unverified);
  * the ``join: sorted | hash`` strategy Choice is forceable through
    ``compile(...)`` and chosen by ``optimize="cost"`` from the key-domain
    statistics (low NDV → hash, domain past the bucket cap → sorted);
  * ``FuseJoinGroupAgg`` collapses MaskSelect → HashJoinDirect →
    GroupAggDirect into one ``vec.FusedJoinGroupAgg`` that never
    materializes the join, equal to the unfused plan and the oracle — on
    the jitted runtime path and the ``grouped_join_agg`` Pallas kernel;
  * resource admission prices the direct table and rejects/degrades plans
    whose bucket table exceeds the byte budget (``join=sorted`` rung);
  * on spmd, both tiers match the oracle and the costed search picks hash
    for the bounded-key join-group shape (subprocess: own device fleet).
"""

import json
import subprocess
import sys
import textwrap
import warnings
from pathlib import Path

import numpy as np
import pytest

from repro.compiler import PlanCache
from repro.core.expr import AggSpec, col
from repro.frontends.dataflow import Context, count_, sum_
from repro.launch.hermetic import subprocess_env
from repro.relational import runtime as rt
from repro.relational.runtime import VecTable
from repro.robust.admission import AdmissionError, estimate_peak_bytes

ROOT = Path(__file__).resolve().parents[1]


def _rows(table):
    """Valid rows of a VecTable as a dict of numpy arrays."""
    v = np.asarray(table.valid)
    return {k: np.asarray(c)[v] for k, c in table.cols.items()}


def _sorted_rows(table, keys):
    arrs = [np.asarray(table[k]) for k in keys]
    order = np.lexsort(tuple(reversed(arrs)))
    return {k: np.asarray(v)[order] for k, v in table.items()}


def _assert_tables_equal(got, want, keys, rtol=1e-4):
    got, want = _sorted_rows(got, keys), _sorted_rows(want, keys)
    assert set(got) == set(want)
    for k in got:
        g, w = np.asarray(got[k]), np.asarray(want[k])
        assert g.shape == w.shape, (k, g.shape, w.shape)
        if np.issubdtype(g.dtype, np.floating) or np.issubdtype(w.dtype, np.floating):
            np.testing.assert_allclose(g, w.astype(g.dtype), rtol=rtol, err_msg=k)
        else:
            np.testing.assert_array_equal(g, w, err_msg=k)


# ---------------------------------------------------------------------------
# runtime tier: hash_join_direct ≡ sort_by_key + merge_join_sorted
# ---------------------------------------------------------------------------


class TestRuntimeHashJoin:
    def _tables(self, lk_cols, rk_cols, n=400, m=64, lcap=512, rcap=64,
                seed=0, lvalid=None, rvalid=None):
        import jax.numpy as jnp
        rng = np.random.default_rng(seed)
        ldata = dict(lk_cols)
        ldata["x"] = rng.normal(size=n).astype(np.float32)
        rdata = dict(rk_cols)
        rdata["y"] = rng.normal(size=m).astype(np.float32)
        left = VecTable.from_numpy(ldata, lcap)
        right = VecTable.from_numpy(rdata, rcap)
        if lvalid is not None:
            left = VecTable(left.cols, jnp.asarray(lvalid, bool))
        if rvalid is not None:
            right = VecTable(right.cols, jnp.asarray(rvalid, bool))
        return left, right

    def _check(self, left, right, left_on, right_on, domains):
        cap = left.capacity
        hashed = rt.hash_join_direct(left, right, left_on, right_on, cap,
                                     key_domains=domains)
        srt = rt.merge_join_sorted(left, rt.sort_by_key(right, right_on),
                                   left_on, right_on, cap,
                                   key_domains=domains if len(left_on) > 1 else None)
        h, s = _rows(hashed), _rows(srt)
        assert set(h) == set(s)
        for k in h:
            np.testing.assert_allclose(h[k], s[k], rtol=1e-6, err_msg=k)
        return h

    def test_int_keys_duplicate_probe(self):
        rng = np.random.default_rng(1)
        lk = rng.integers(0, 64, 400).astype(np.int32)  # many probe dups
        left, right = self._tables({"k": lk}, {"k2": np.arange(64, dtype=np.int32)})
        h = self._check(left, right, ("k",), ("k2",), ((0, 63),))
        assert len(h["x"]) == 400  # every probe row matched

    def test_composite_keys(self):
        rng = np.random.default_rng(2)
        lk1 = rng.integers(0, 8, 400).astype(np.int32)
        lk2 = (rng.integers(0, 4, 400) * 70_000).astype(np.int32)  # >16-bit
        grid = np.stack(np.meshgrid(np.arange(8), np.arange(4) * 70_000),
                        -1).reshape(-1, 2)
        left, right = self._tables(
            {"a": lk1, "b": lk2},
            {"a2": grid[:, 0].astype(np.int32), "b2": grid[:, 1].astype(np.int32)},
            m=32, rcap=32)
        self._check(left, right, ("a", "b"), ("a2", "b2"),
                    ((0, 7), (0, 210_000)))

    def test_partial_match_and_out_of_domain(self):
        """Probe keys outside the declared domain (and unmatched in-domain
        keys) must drop — a clipped bucket id must not fabricate a match."""
        lk = np.array([0, 1, 5, 200, -3, 7] * 50, np.int32)
        left, right = self._tables({"k": lk}, {"k2": np.arange(8, dtype=np.int32)},
                                   n=300, m=8, rcap=8)
        h = self._check(left, right, ("k",), ("k2",), ((0, 7),))
        # 200 and -3 are out of domain; 0,1,5,7 match
        assert len(h["x"]) == 4 * 50
        assert set(h["k"].tolist()) == {0, 1, 5, 7}

    def test_duplicate_build_keys_first_occurrence(self):
        """Both vec tiers keep the FIRST build row per key (PK-FK)."""
        left, right = self._tables(
            {"k": np.array([3, 3, 1], np.int32)},
            {"k2": np.array([1, 3, 3, 1], np.int32)},
            n=3, m=4, lcap=4, rcap=4)
        h = self._check(left, right, ("k",), ("k2",), ((0, 3),))
        ry = np.asarray(right.cols["y"])
        np.testing.assert_allclose(h["y"], [ry[1], ry[1], ry[0]])

    def test_empty_and_all_invalid(self):
        left, right = self._tables(
            {"k": np.zeros(16, np.int32)}, {"k2": np.arange(4, dtype=np.int32)},
            n=16, m=4, lcap=16, rcap=4, lvalid=np.zeros(16, bool))
        h = self._check(left, right, ("k",), ("k2",), ((0, 3),))
        assert len(h["x"]) == 0
        # all-invalid build side: no probe row can match
        left2, right2 = self._tables(
            {"k": np.zeros(16, np.int32)}, {"k2": np.arange(4, dtype=np.int32)},
            n=16, m=4, lcap=16, rcap=4, rvalid=np.zeros(4, bool))
        assert len(self._check(left2, right2, ("k",), ("k2",), ((0, 3),))["x"]) == 0

    def test_dynamic_bounds_both_branches(self):
        """The joint-dynamic-bounds variant: when the measured key span fits
        ``num_buckets`` it takes the direct branch, otherwise the in-trace
        sorted fallback — both must equal the static answer."""
        rng = np.random.default_rng(3)
        lk = rng.integers(0, 32, 200).astype(np.int32)
        left, right = self._tables({"k": lk}, {"k2": np.arange(32, dtype=np.int32)},
                                   n=200, m=32, lcap=256, rcap=32)
        want = _rows(rt.hash_join_direct(left, right, ("k",), ("k2",), 256,
                                         key_domains=((0, 31),)))
        for nb in (64, 8):  # fits / does not fit
            got = _rows(rt.hash_join_direct(left, right, ("k",), ("k2",), 256,
                                            num_buckets=nb))
            for k in want:
                np.testing.assert_allclose(got[k], want[k], rtol=1e-6, err_msg=k)

    def test_requires_domains_or_buckets(self):
        left, right = self._tables({"k": np.zeros(8, np.int32)},
                                   {"k2": np.zeros(4, np.int32)},
                                   n=8, m=4, lcap=8, rcap=4)
        with pytest.raises(ValueError, match="needs a static num_buckets"):
            rt.hash_join_direct(left, right, ("k",), ("k2",), 8)


# ---------------------------------------------------------------------------
# forced strategies + the costed choice, through compile(...)
# ---------------------------------------------------------------------------


@pytest.fixture()
def join_ctx():
    rng = np.random.default_rng(7)
    n, m = 4096, 256
    ctx = Context(pad_to=512)
    ctx.register("orders", {
        "custkey": rng.integers(0, m, n).astype(np.int32),
        "price": rng.gamma(2.0, 100.0, n).astype(np.float32),
        "year": rng.integers(2018, 2026, n).astype(np.int32),
    })
    ctx.register("customer", {
        "ckey": np.arange(m).astype(np.int32),
        "nation": rng.integers(0, 8, m).astype(np.int32),
    })
    return ctx


def join_query(ctx):
    return ctx.table("orders").join(ctx.table("customer"),
                                    left_on=("custkey",), right_on=("ckey",))


def q3_query(ctx):
    """The TPC-H Q3/Q12 shape: select → join → group-aggregate."""
    return (ctx.table("orders").filter(col("year") >= 2020)
            .join(ctx.table("customer"), left_on=("custkey",), right_on=("ckey",))
            .group_by("nation", max_groups=16)
            .agg(sum_("price").as_("rev"), count_().as_("n")))


class TestStrategyChoice:
    def test_forced_hash_and_sorted_match_oracle(self, join_ctx):
        q = join_query(join_ctx)
        want = join_ctx.execute(q, target="interp")
        progs = {}
        for label in ("sorted", "hash"):
            res = join_ctx.compile(q, strategy={"join": label},
                                   cache=PlanCache())
            progs[label] = res.program.opcodes()
            (out,) = res(join_ctx.sources())
            _assert_tables_equal(out.to_numpy(), want, ("custkey", "price"))
        assert "vec.MergeJoinSorted" in progs["sorted"]
        assert "vec.HashJoinDirect" not in progs["sorted"]
        assert "vec.HashJoinDirect" in progs["hash"]
        assert "vec.SortByKey" not in progs["hash"]
        assert "vec.MergeJoinSorted" not in progs["hash"]

    def test_cost_low_ndv_selects_hash(self, join_ctx):
        res = join_ctx.compile(join_query(join_ctx), optimize="cost",
                               cache=PlanCache())
        assert dict(res.strategy)["join"] == "hash"
        assert "vec.HashJoinDirect" in res.program.opcodes()
        labels = [c.label() for c in res.decision.candidates]
        assert any("join=sorted" in l for l in labels)

    def test_cost_huge_domain_selects_sorted(self):
        """Join keys spread over a ~2^21 *raw* domain but only 2048 distinct
        values: the raw direct table would not fit the bucket cap (forcing
        encode=raw warns and degrades to sorted), while dictionary encoding
        shrinks the domain to rank space and the costed search keeps the
        O(n) hash tier."""
        rng = np.random.default_rng(13)
        n, m = 4096, 2048
        ctx = Context(pad_to=512)
        ctx.register("probe", {
            "k": (rng.integers(0, m, n) * 1024).astype(np.int32),
            "x": rng.normal(size=n).astype(np.float32),
        })
        ctx.register("build", {
            "bk": (np.arange(m) * 1024).astype(np.int32),
            "y": rng.normal(size=m).astype(np.float32),
        })
        q = ctx.table("probe").join(ctx.table("build"),
                                    left_on=("k",), right_on=("bk",))
        # encode=raw forced: the sparse raw span is over budget → warn and
        # degrade the join to the sorted tier, exactly the pre-dictionary
        # behaviour
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            raw = ctx.compile(q, strategy={"join": "hash", "encode": "raw"},
                              cache=PlanCache())
        assert "vec.HashJoinDirect" not in raw.program.opcodes()
        assert "vec.MergeJoinSorted" in raw.program.opcodes()
        assert any("hash_unavailable" in str(w.message) for w in caught)
        # costed search: dictionary ranks fit the cap, so the sort-free
        # tier stays available and wins
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            res = ctx.compile(q, optimize="cost", cache=PlanCache())
        chosen = dict(res.strategy)
        assert chosen["join"] == "hash" and chosen["encode"] == "dict"
        assert "vec.HashJoinDirect" in res.program.opcodes()

    def test_pkfk_unverified_warns(self):
        """Duplicate build-side keys break the PK-FK assumption the vec
        tiers rely on — the lowering must say so out loud."""
        ctx = Context(pad_to=64)
        ctx.register("l", {"k": (np.arange(32) % 4).astype(np.int32),
                           "x": np.ones(32, np.float32)})
        ctx.register("r", {"k2": np.array([0, 1, 2, 3, 0, 1], np.int32),
                           "y": np.arange(6).astype(np.float32)})
        q = ctx.table("l").join(ctx.table("r"), left_on=("k",), right_on=("k2",))
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            ctx.compile(q, strategy={"join": "hash"}, cache=PlanCache())
        msgs = [str(w.message) for w in caught]
        assert any("join_pkfk_unverified" in m for m in msgs)

    def test_join_strategy_is_cache_keyed(self, join_ctx):
        cache = PlanCache()
        q = join_query(join_ctx)
        r1 = join_ctx.compile(q, strategy={"join": "hash"}, cache=cache)
        r2 = join_ctx.compile(q, strategy={"join": "sorted"}, cache=cache)
        r3 = join_ctx.compile(q, strategy={"join": "hash"}, cache=cache)
        assert not r1.cache_hit and not r2.cache_hit and r3.cache_hit

    def test_empty_selection_matches_oracle(self, join_ctx):
        q = (join_ctx.table("orders").filter(col("year") >= 3000)
             .join(join_ctx.table("customer"),
                   left_on=("custkey",), right_on=("ckey",)))
        want = join_ctx.execute(q, target="interp")
        assert len(np.asarray(want["price"]).ravel()) == 0
        for label in ("sorted", "hash"):
            got = join_ctx.execute(q, strategy={"join": label})
            assert len(got["price"]) == 0


# ---------------------------------------------------------------------------
# whole-pipeline fusion: select → join → group as one op / one kernel
# ---------------------------------------------------------------------------


class TestFusedJoinGroupAgg:
    def test_fused_equals_unfused_and_oracle(self, join_ctx):
        q = q3_query(join_ctx)
        want = join_ctx.execute(q, target="interp")
        fused = join_ctx.compile(q, strategy={"join": "hash",
                                              "groupby": "direct"},
                                 cache=PlanCache())
        ops = fused.program.opcodes()
        assert "vec.FusedJoinGroupAgg" in ops
        assert "vec.HashJoinDirect" not in ops  # join never materialized
        assert "vec.GroupAggDirect" not in ops
        assert "vec.MaskSelect" not in ops  # predicate folded in
        (out,) = fused(join_ctx.sources())
        _assert_tables_equal(out.to_numpy(), want, ("nation",))

        unfused = join_ctx.compile(q, strategy={"join": "hash",
                                                "groupby": "direct"},
                                   fuse=False, cache=PlanCache())
        assert "vec.HashJoinDirect" in unfused.program.opcodes()
        (out2,) = unfused(join_ctx.sources())
        _assert_tables_equal(out2.to_numpy(), want, ("nation",))

    def test_fused_kernel_matches_oracle(self, join_ctx):
        q = q3_query(join_ctx)
        want = join_ctx.execute(q, target="interp")
        res = join_ctx.compile(q, strategy={"join": "hash",
                                            "groupby": "direct"},
                               use_kernels=True, cache=PlanCache())
        assert "vec.FusedJoinGroupAgg" in res.program.opcodes()
        (out,) = res(join_ctx.sources())
        _assert_tables_equal(out.to_numpy(), want, ("nation",))

    def test_fused_runtime_op_matches_composition(self):
        """rt.fused_join_group_agg ≡ mask_select → hash_join → group_agg."""
        rng = np.random.default_rng(5)
        n, m = 512, 16
        left = VecTable.from_numpy({
            "k": rng.integers(0, m, n).astype(np.int32),
            "x": rng.normal(size=n).astype(np.float32)}, n)
        right = VecTable.from_numpy({
            "k2": np.arange(m).astype(np.int32),
            "g": rng.integers(0, 4, m).astype(np.int32),
            "w": rng.normal(size=m).astype(np.float32)}, m)
        pred = col("x") > 0.0
        aggs = (AggSpec("sum", col("x"), "sx"), AggSpec("count", col("x"), "c"),
                AggSpec("min", col("w"), "mw"))
        fused = rt.fused_join_group_agg(
            left, right, ("k",), ("k2",),
            join_key_domains=((0, m - 1),), join_num_buckets=m,
            keys=("g",), aggs=aggs, max_groups=8,
            key_domains=((0, 3),), num_buckets=4, pred=pred)
        sel = rt.mask_select(left, pred)
        joined = rt.hash_join_direct(sel, right, ("k",), ("k2",), n,
                                     key_domains=((0, m - 1),))
        ref = rt.group_agg_direct(joined, ("g",), aggs, 8, ((0, 3),), 4)
        f, r = _rows(fused), _rows(ref)
        for k in f:
            np.testing.assert_allclose(f[k], r[k], rtol=1e-5, err_msg=k)


# ---------------------------------------------------------------------------
# resource admission: the direct table is priced, over-budget degrades
# ---------------------------------------------------------------------------


def make_big_domain_join_ctx():
    """Join keys over a ~2^19 domain: admissible for lowering (under the
    bucket cap) but the ~2 MB direct table busts a 1 MB budget."""
    rng = np.random.default_rng(17)
    n, m = 4096, 512
    ctx = Context(pad_to=512)
    ctx.register("probe", {
        "k": (rng.integers(0, m, n) * 1024).astype(np.int32),
        "x": rng.normal(size=n).astype(np.float32),
    })
    ctx.register("build", {
        "bk": (np.arange(m) * 1024).astype(np.int32),
        "y": rng.normal(size=m).astype(np.float32),
    })
    return ctx


class TestJoinAdmission:
    BUDGET = 1_000_000

    def test_direct_table_priced(self, join_ctx):
        res = join_ctx.compile(join_query(join_ctx), strategy={"join": "hash"},
                               cache=False, guard=False)
        est = estimate_peak_bytes(res.program)
        assert est.peak_site == "vec.HashJoinDirect"
        sites = dict(est.breakdown)
        assert sites["vec.HashJoinDirect"] > 256 * 4  # includes the table

    def test_over_budget_rejected_without_guard(self):
        ctx = make_big_domain_join_ctx()
        q = ctx.table("probe").join(ctx.table("build"),
                                    left_on=("k",), right_on=("bk",))
        with pytest.raises(AdmissionError, match="resource admission"):
            ctx.compile(q, strategy={"join": "hash"}, cache=False,
                        memory_budget=self.BUDGET, guard=False)

    def test_over_budget_degrades_to_sorted_with_guard(self):
        ctx = make_big_domain_join_ctx()
        q = ctx.table("probe").join(ctx.table("build"),
                                    left_on=("k",), right_on=("bk",))
        want = ctx.execute(q, target="interp")
        with warnings.catch_warnings(record=True):
            warnings.simplefilter("always")
            res = ctx.compile(q, strategy={"join": "hash"}, cache=PlanCache(),
                              memory_budget=self.BUDGET)
        assert ("join", "sorted") in res.strategy
        assert res.degraded
        assert "vec.MergeJoinSorted" in res.program.opcodes()
        (out,) = res(ctx.sources())
        _assert_tables_equal(out.to_numpy(), want, ("k", "x"))


# ---------------------------------------------------------------------------
# spmd acceptance: both tiers ≡ oracle, cost picks hash (own device fleet)
# ---------------------------------------------------------------------------

SPMD_JOIN_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np

    from repro.compiler import compile as cvm_compile
    from repro.frontends.dataflow import Context, count_, sum_

    rng = np.random.default_rng(21)
    n, m = 8192, 128
    ctx = Context(pad_to=1024)
    ctx.register("orders", {
        "custkey": rng.integers(0, m, n).astype(np.int32),
        "price": rng.gamma(2.0, 100.0, n).astype(np.float32),
    })
    ctx.register("customer", {
        "ckey": np.arange(m).astype(np.int32),
        "nation": rng.integers(0, 8, m).astype(np.int32),
    })
    q = (ctx.table("orders")
         .join(ctx.table("customer"), left_on=("custkey",), right_on=("ckey",))
         .group_by("nation", max_groups=16)
         .agg(sum_("price").as_("rev"), count_().as_("n")))
    program = q.program()
    catalog = ctx.catalog()
    out = {}

    res = cvm_compile(program, target="spmd", parallel=8, catalog=catalog,
                      optimize="cost", cache=False)
    out["strategy"] = dict(res.strategy)

    want = ctx.execute(q, target="interp")
    o_w = np.argsort(np.asarray(want["nation"]).ravel())
    for label in ("sorted", "hash"):
        r = cvm_compile(program, target="spmd", parallel=8, catalog=catalog,
                        strategy={"join": label}, cache=False)
        (got_t,) = r(ctx.sources())
        got = got_t.to_numpy()
        o_g = np.argsort(got["nation"])
        np.testing.assert_allclose(got["rev"][o_g],
                                   np.asarray(want["rev"]).ravel()[o_w],
                                   rtol=1e-4)
        np.testing.assert_array_equal(got["n"][o_g],
                                      np.asarray(want["n"]).ravel()[o_w])
        out[label + "_ok"] = True
        out[label + "_ops"] = sorted(set(
            op for p in r.program.walk() for op in p.opcodes()))
    print("RESULTS" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def spmd_join_results():
    proc = subprocess.run(
        [sys.executable, "-c", SPMD_JOIN_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env=subprocess_env(ROOT),
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS")][0]
    return json.loads(line[len("RESULTS"):])


class TestSpmdJoin:
    def test_cost_selects_hash_on_spmd(self, spmd_join_results):
        assert spmd_join_results["strategy"]["join"] == "hash"

    def test_both_tiers_match_interp(self, spmd_join_results):
        assert spmd_join_results["sorted_ok"]
        assert spmd_join_results["hash_ok"]
        assert "vec.MergeJoinSorted" in spmd_join_results["sorted_ops"]
        assert "vec.HashJoinDirect" in spmd_join_results["hash_ops"]
