"""Substrate tests: checkpointing (atomic/elastic), fault runner, data
pipeline determinism, optimizer correctness."""

import os
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import TokenPipeline
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.fault import StepRunner
from repro.train.optimizer import AdamW, SGD


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(tmp_path, n_shards=3)
        tree = {"w": jnp.arange(10, dtype=jnp.float32),
                "nested": {"b": jnp.ones((4, 2)), "step": jnp.asarray(7)}}
        mgr.save(5, tree, extra={"step": 5})
        out, extra = mgr.restore(tree)
        assert extra["step"] == 5
        np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(10))
        np.testing.assert_array_equal(np.asarray(out["nested"]["b"]), np.ones((4, 2)))

    def test_atomicity_no_tmp_left(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, {"x": jnp.zeros(3)})
        assert not list(Path(tmp_path).glob("*.tmp"))
        assert mgr.latest_step() == 1

    def test_gc_keeps_last_k(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2)
        for s in range(5):
            mgr.save(s, {"x": jnp.full(3, s)})
        steps = sorted(p.name for p in Path(tmp_path).glob("step_*"))
        assert len(steps) == 2 and steps[-1].endswith("00000004")

    def test_corruption_detected(self, tmp_path):
        mgr = CheckpointManager(tmp_path, n_shards=2)
        mgr.save(3, {"x": jnp.arange(100, dtype=jnp.float32)})
        shard = next(Path(tmp_path).glob("step_*/shard_0.npz"))
        shard.write_bytes(shard.read_bytes()[:-10] + b"corruption")
        with pytest.raises(IOError, match="hash mismatch"):
            mgr.restore({"x": jnp.zeros(100)})

    def test_elastic_reshard_onto_new_sharding(self, tmp_path):
        """Save under one layout, restore onto explicit device shardings —
        the 2-pod → 1-pod elastic path (placement-agnostic checkpoints)."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        mgr = CheckpointManager(tmp_path, n_shards=4)
        big = jnp.arange(64 * 8, dtype=jnp.float32).reshape(64, 8)
        mgr.save(1, {"w": big})
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((1,), ("data",))
        target = jax.device_put(jnp.zeros((64, 8)), NamedSharding(mesh, P("data")))
        out, _ = mgr.restore({"w": target})
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(big))
        assert out["w"].sharding == target.sharding


class TestFaultRunner:
    def test_retry_restores_from_checkpoint(self, tmp_path):
        """A step that crashes once must restore and continue to completion."""
        ckpt = CheckpointManager(tmp_path)
        calls = {"n": 0, "failed": False}

        def step(x, batch):
            calls["n"] += 1
            if calls["n"] == 7 and not calls["failed"]:
                calls["failed"] = True
                raise RuntimeError("simulated device loss")
            return x + batch, {"loss": float(x)}

        runner = StepRunner(step_fn=step, ckpt=ckpt, ckpt_every=3, max_retries=2)
        (final,) = runner.run((jnp.zeros(()),), iter(lambda: jnp.ones(()), None),
                              num_steps=10)
        assert calls["failed"]
        assert float(final) == 10.0 or float(final) >= 9.0  # restored + completed
        assert len(runner.history) >= 10

    def test_straggler_detection(self, tmp_path):
        import time as _t

        ckpt = CheckpointManager(tmp_path)
        calls = {"n": 0}

        def step(x, batch):
            calls["n"] += 1
            if calls["n"] == 5:
                _t.sleep(0.25)
            else:
                _t.sleep(0.01)
            return x, {"loss": 0.0}

        runner = StepRunner(step_fn=step, ckpt=ckpt, ckpt_every=100,
                            straggler_factor=3.0)
        runner.run((jnp.zeros(()),), iter(lambda: jnp.ones(()), None), num_steps=8)
        assert runner.stragglers >= 1
        assert any(h.straggler for h in runner.history)


class TestDataPipeline:
    def test_deterministic_resume(self):
        p = TokenPipeline(vocab=1000, seq_len=32, global_batch=8, seed=3)
        a = p.batch_at(17)
        b = p.batch_at(17)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_host_sharding_partitions_batch(self):
        full = TokenPipeline(vocab=100, seq_len=16, global_batch=8, seed=1)
        h0 = TokenPipeline(vocab=100, seq_len=16, global_batch=8, seed=1,
                           n_hosts=2, host_id=0)
        h1 = TokenPipeline(vocab=100, seq_len=16, global_batch=8, seed=1,
                           n_hosts=2, host_id=1)
        f = full.batch_at(4)["tokens"]
        np.testing.assert_array_equal(h0.batch_at(4)["tokens"], f[0::2])
        np.testing.assert_array_equal(h1.batch_at(4)["tokens"], f[1::2])
        assert h0.batch_at(4)["tokens"].shape[0] == 4

    def test_labels_are_shifted_tokens(self):
        p = TokenPipeline(vocab=50, seq_len=24, global_batch=2, seed=0)
        b = p.batch_at(0)
        # tokens[t+1] == labels[t] wherever no noise flip happened between views
        assert b["tokens"].shape == (2, 24) and b["labels"].shape == (2, 24)

    def test_prefetching_matches_direct(self):
        p = TokenPipeline(vocab=100, seq_len=8, global_batch=2, seed=9)
        it = p.prefetching(start_step=5)
        s, batch = next(it)
        assert s == 5
        np.testing.assert_array_equal(batch["tokens"], p.batch_at(5)["tokens"])
        it.close()


class TestOptimizer:
    def test_adamw_converges_quadratic(self):
        opt = AdamW(lr=0.1, weight_decay=0.0)
        params = {"x": jnp.asarray(5.0)}
        state = opt.init(params)

        def loss(p):
            return (p["x"] - 2.0) ** 2

        for _ in range(200):
            g = jax.grad(loss)(params)
            params, state = opt.update(g, state, params)
        assert abs(float(params["x"]) - 2.0) < 1e-2

    def test_grad_clip_bounds_update(self):
        opt = AdamW(lr=1.0, grad_clip=1e-3, weight_decay=0.0)
        params = {"x": jnp.asarray(0.0)}
        state = opt.init(params)
        g = {"x": jnp.asarray(1e6)}
        p2, _ = opt.update(g, state, params)
        assert abs(float(p2["x"])) < 1.5  # clip kept the step sane

    def test_sgd_momentum(self):
        opt = SGD(lr=0.1, momentum=0.0)
        params = {"x": jnp.asarray(1.0)}
        state = opt.init(params)
        p2, _ = opt.update({"x": jnp.asarray(1.0)}, state, params)
        assert float(p2["x"]) == pytest.approx(0.9)


def test_train_driver_end_to_end(tmp_path):
    """Reduced-config training through the full driver: loss drops,
    checkpoint written, resume works."""
    from repro.launch import train as train_mod

    losses = train_mod.main([
        "--arch", "qwen2-1.5b", "--reduced", "--steps", "12",
        "--batch", "4", "--seq", "32", "--ckpt-every", "6",
        "--ckpt-dir", str(tmp_path),
    ])
    assert losses[-1] < losses[0]
    # resume from the checkpoint
    losses2 = train_mod.main([
        "--arch", "qwen2-1.5b", "--reduced", "--steps", "4",
        "--batch", "4", "--seq", "32", "--ckpt-every", "100",
        "--ckpt-dir", str(tmp_path), "--resume",
    ])
    assert losses2[0] < losses[0]  # continued from trained weights
