"""Observability: spans, exporters, runtime cardinality taps, feedback.

Covers the tracer core (nesting, disabled-mode fast path), the Chrome-trace
exporter's schema, the measured-cardinality capture on TPC-H Q1 across the
interp and local backends (against reference row counts computed in numpy),
the estimate-vs-actual report in ``explain()``, the plan-cache/plan-store
counters, corrupt-store warnings, and the feedback catalog that closes the
loop back into the statistics and cost calibration.
"""

import json
import warnings

import numpy as np
import pytest

from repro.compiler import PlanCache, compile as cvm_compile
from repro.compiler.cost import EXEC_CALIBRATION, CostCalibration
from repro.compiler.store import PlanStore
from repro.obs import (
    FEEDBACK,
    FeedbackCatalog,
    NULL_SPAN,
    ObsWarning,
    Tracer,
    chrome_trace,
    get_tracer,
    tracing,
    write_chrome_trace,
)
from repro.relational import tpch


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------


class TestTracer:
    def test_span_nesting_parents(self):
        tr = Tracer()
        with tr.span("outer", cat="a") as outer:
            with tr.span("inner", cat="b") as inner:
                pass
        assert inner.parent_id == outer.span_id
        assert outer.parent_id is None
        # children record before parents (exit order)
        assert [s.name for s in tr.spans] == ["inner", "outer"]
        assert all(s.dur_s >= 0.0 for s in tr.spans)

    def test_span_attributes_set_late(self):
        tr = Tracer()
        with tr.span("work", rows=10) as sp:
            sp.set(result="ok")
        assert tr.spans[0].args == {"rows": 10, "result": "ok"}

    def test_disabled_mode_returns_shared_null_span(self):
        tr = Tracer(enabled=False)
        # zero-allocation fast path: every disabled span() is the same object
        assert tr.span("a") is tr.span("b") is NULL_SPAN
        with tr.span("a") as sp:
            sp.set(ignored=1)
        assert tr.spans == [] and tr.counters == {}
        tr.counter("n")
        tr.observe("h", 1.0)
        tr.event("e")
        assert tr.counters == {} and tr.histograms == {} and tr.events == []

    def test_global_tracer_disabled_by_default(self):
        assert get_tracer().enabled is False
        assert get_tracer().span("x") is NULL_SPAN

    def test_tracing_context_installs_and_restores(self):
        before = get_tracer()
        with tracing() as tr:
            assert get_tracer() is tr and tr.enabled
        assert get_tracer() is before

    def test_counters_and_histograms(self):
        tr = Tracer()
        tr.counter("hits")
        tr.counter("hits", 2.0)
        for v in (1.0, 2.0, 3.0, 4.0):
            tr.observe("lat", v)
        assert tr.counters["hits"] == 3.0
        h = tr.histogram_summary("lat")
        assert h["count"] == 4 and h["sum"] == 10.0 and h["min"] == 1.0
        assert h["max"] == 4.0 and h["p50"] == 3.0
        m = tr.metrics()
        assert m["counters"]["hits"] == 3.0
        assert m["histograms"]["lat"]["mean"] == 2.5

    def test_max_events_bounds_spans(self):
        tr = Tracer(max_events=2)
        for i in range(5):
            with tr.span(f"s{i}"):
                pass
        assert len(tr.spans) == 2 and tr.dropped == 3
        assert tr.metrics()["dropped"] == 3


# ---------------------------------------------------------------------------
# chrome trace export
# ---------------------------------------------------------------------------


class TestChromeTrace:
    def test_schema_and_roundtrip(self, tmp_path):
        tr = Tracer()
        with tr.span("outer", cat="compile"):
            with tr.span("inner", cat="compile.pass", stage="fuse"):
                pass
        tr.counter("plan_cache.hit", 3)
        tr.event("plan_store.corrupt", path="/x.json")
        path = write_chrome_trace(tmp_path / "t.json", tr)
        doc = json.loads(path.read_text())

        assert set(doc) == {"traceEvents", "displayTimeUnit", "metadata"}
        events = doc["traceEvents"]
        phases = {e["ph"] for e in events}
        assert phases == {"M", "X", "i", "C"}
        for e in events:
            assert {"name", "ph", "pid", "tid"} <= set(e)
        complete = [e for e in events if e["ph"] == "X"]
        assert [e["name"] for e in complete] == ["inner", "outer"]
        for e in complete:
            assert e["ts"] >= 0.0 and e["dur"] >= 0.0  # microseconds
        inner = complete[0]
        outer = complete[1]
        assert inner["args"]["parent"] == outer["id"]
        assert inner["args"]["stage"] == "fuse"
        counters = [e for e in events if e["ph"] == "C"]
        assert counters[0]["args"]["value"] == 3
        assert doc["metadata"]["metrics"]["counters"]["plan_cache.hit"] == 3

    def test_nesting_by_interval_containment(self):
        tr = Tracer()
        with tr.span("parent"):
            with tr.span("child"):
                pass
        doc = chrome_trace(tr)
        by_name = {e["name"]: e for e in doc["traceEvents"] if e["ph"] == "X"}
        p, c = by_name["parent"], by_name["child"]
        assert p["ts"] <= c["ts"]
        assert c["ts"] + c["dur"] <= p["ts"] + p["dur"] + 1e-3


# ---------------------------------------------------------------------------
# traced execution: measured cardinalities on TPC-H Q1
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def q1_setup():
    tables = tpch.generate(sf=0.002, seed=7)
    ctx = tpch.make_context(tables, pad_to=256)
    frame = tpch.QUERIES["q1"](ctx)
    # reference row counts straight from the data
    li = tables["lineitem"]
    rf = np.asarray(li["l_returnflag"])
    ls = np.asarray(li["l_linestatus"])
    n_groups = len(np.unique(np.rec.fromarrays([rf, ls], names=["a", "b"])))
    return tables, ctx, frame, len(rf), n_groups


class TestMeasuredCardinalities:
    def _run(self, ctx, frame, target, sources):
        with tracing():
            res = ctx.compile(frame, target=target, cache=PlanCache())
            res(sources)
        return res

    def test_q1_local_cardinalities(self, q1_setup):
        tables, ctx, frame, n_rows, n_groups = q1_setup
        res = self._run(ctx, frame, "local", ctx.sources())
        prof = res.profile
        assert prof is not None and prof.target == "local"
        by_op = {o.opcode: o for o in prof.observations}
        assert by_op["vec.ScanVec"].rows_out == n_rows
        assert by_op["vec.ScanVec"].table == "lineitem"
        # the grouped aggregation's output cardinality is the group count
        agg = next(o for o in prof.observations
                   if o.opcode in ("vec.GroupAggSorted", "vec.GroupAggDirect",
                                   "vec.FusedSelectAgg"))
        assert agg.rows_out == n_groups
        # every observation joined an estimate and computed its miss
        assert all(o.est_rows is not None for o in prof.observations)
        assert all(o.rel_miss is not None for o in prof.observations)

    def test_q1_interp_cardinalities_and_walls(self, q1_setup):
        tables, ctx, frame, n_rows, n_groups = q1_setup
        res = self._run(ctx, frame, "interp", tables)
        prof = res.profile
        by_op = {o.opcode: o for o in prof.observations}
        assert by_op["rel.Scan"].rows_out == n_rows
        assert by_op["rel.GroupByAggr"].rows_out == n_groups
        # the eager interpreter times individual operators
        assert all(o.wall_s is not None and o.wall_s >= 0.0
                   for o in prof.observations)

    def test_q1_interp_local_agree(self, q1_setup):
        """Both backends must measure the same selection cardinality."""
        tables, ctx, frame, n_rows, n_groups = q1_setup
        local = self._run(ctx, frame, "local", ctx.sources()).profile
        interp = self._run(ctx, frame, "interp", tables).profile
        sel_local = next(o.rows_out for o in local.observations
                         if o.opcode in ("vec.MaskSelect", "vec.FusedSelectAgg"))
        sel_interp = next(o.rows_out for o in interp.observations
                          if o.opcode == "rel.Select")
        assert sel_local == sel_interp

    def test_q1_trace_has_nested_compile_and_execute_spans(self, q1_setup):
        tables, ctx, frame, _, _ = q1_setup
        with tracing() as tr:
            res = ctx.compile(frame, target="local", cache=PlanCache())
            res(ctx.sources())
        doc = chrome_trace(tr)
        by_cat = {}
        for e in doc["traceEvents"]:
            if e["ph"] == "X":
                by_cat.setdefault(e.get("cat"), []).append(e)
        # a top-level compile span with nested per-pass spans
        assert len(by_cat["compile"]) == 1
        compile_id = by_cat["compile"][0]["id"]
        assert by_cat["compile.pass"]
        assert all(e["args"].get("parent") for e in by_cat["compile.pass"])
        assert any(e["args"]["parent"] == compile_id
                   for e in by_cat["compile.pass"])
        # an execute span plus per-operator cardinality annotations
        assert by_cat["execute"]
        ops = by_cat["execute.op"]
        assert ops and all("rows_out" in e["args"] for e in ops)

    def test_untraced_call_attaches_no_profile(self, q1_setup):
        tables, ctx, frame, _, _ = q1_setup
        res = ctx.compile(frame, target="local", cache=PlanCache())
        res(ctx.sources())
        assert res.profile is None


# ---------------------------------------------------------------------------
# explain(): cache provenance + estimate-vs-actual report
# ---------------------------------------------------------------------------


class TestExplain:
    def test_cache_hit_source_memory(self, q1_setup):
        tables, ctx, frame, _, _ = q1_setup
        cache = PlanCache()
        first = ctx.compile(frame, target="local", cache=cache)
        again = ctx.compile(frame, target="local", cache=cache)
        assert "cache=miss" in first.explain()
        assert again.cache_hit and again.cache_source == "memory"
        assert "cache=hit source=memory" in again.explain()
        assert cache.stats["hits"] == 1 and cache.stats["misses"] == 1

    def test_plan_cache_counters_reach_tracer(self, q1_setup):
        tables, ctx, frame, _, _ = q1_setup
        with tracing() as tr:
            cache = PlanCache()
            ctx.compile(frame, target="local", cache=cache)
            ctx.compile(frame, target="local", cache=cache)
        assert tr.counters["plan_cache.miss"] == 1
        assert tr.counters["plan_cache.hit"] == 1

    def test_plan_cache_eviction_counted(self):
        cache = PlanCache(capacity=1)
        cache.store(("a",), "r1")
        cache.store(("b",), "r2")
        assert cache.stats["evictions"] == 1 and len(cache) == 1

    def test_estimate_vs_actual_table_in_explain(self, q1_setup):
        tables, ctx, frame, n_rows, _ = q1_setup
        with tracing():
            res = ctx.compile(frame, target="local", cache=PlanCache())
            res(ctx.sources())
        text = res.explain()
        assert "| op | register | est rows | actual rows | miss | wall ms |"\
            in text
        assert f"{n_rows:,}" in text  # the measured scan cardinality
        assert "worst cardinality miss" in text

    def test_metrics_dict_is_json_ready(self, q1_setup):
        tables, ctx, frame, _, _ = q1_setup
        with tracing():
            res = ctx.compile(frame, target="local", cache=PlanCache())
            res(ctx.sources())
            m = res.metrics()
        json.dumps(m)  # must not raise
        assert m["cache_source"] == "miss"
        assert m["runtime"]["operators"]
        assert m["tracer"]["counters"]


# ---------------------------------------------------------------------------
# plan store: hit/miss/corruption
# ---------------------------------------------------------------------------


class TestPlanStoreObs:
    def test_corrupt_plan_warns_with_path_and_reason(self, tmp_path):
        store = PlanStore(tmp_path)
        store.save_plan("abc", {"strategy": []})
        (tmp_path / "abc.json").write_text("{not json")
        with pytest.warns(ObsWarning, match="plan_store.corrupt") as rec:
            assert store.load_plan("abc") is None
        msg = str(rec[0].message)
        assert "abc.json" in msg and "reason=" in msg

    def test_corrupt_counter_and_event_when_tracing(self, tmp_path):
        store = PlanStore(tmp_path)
        (tmp_path / "bad.json").write_text("][")
        with tracing() as tr:
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                store.load_plan("bad")
        assert tr.counters["plan_store.corrupt"] == 1
        events = [e for e in tr.events if e["name"] == "plan_store.corrupt"]
        assert events and "bad.json" in events[0]["path"]

    def test_missing_plan_is_a_miss_not_a_warning(self, tmp_path):
        store = PlanStore(tmp_path)
        with tracing() as tr:
            with warnings.catch_warnings():
                warnings.simplefilter("error", ObsWarning)
                assert store.load_plan("nope") is None
        assert tr.counters["plan_store.miss"] == 1

    def test_hit_counter(self, tmp_path):
        store = PlanStore(tmp_path)
        store.save_plan("k", {"strategy": [["groupby", "direct"]]})
        with tracing() as tr:
            assert store.load_plan("k")["strategy"]
        assert tr.counters["plan_store.hit"] == 1

    def test_corrupt_calibration_warns_and_defaults(self, tmp_path):
        store = PlanStore(tmp_path)
        (tmp_path / "calibration.json").write_text("~~~")
        with pytest.warns(ObsWarning, match="plan_store.corrupt"):
            calib = store.load_calibration()
        assert calib.n == 0


# ---------------------------------------------------------------------------
# feedback: measured rows → observed statistics + runtime calibration
# ---------------------------------------------------------------------------


class TestFeedback:
    def test_feedback_accumulates_scan_rows(self, q1_setup):
        tables, ctx, frame, n_rows, _ = q1_setup
        FEEDBACK.clear()
        with tracing():
            res = ctx.compile(frame, target="local", cache=PlanCache())
            res(ctx.sources())
        assert FEEDBACK.runs == 1
        assert FEEDBACK.table_rows["lineitem"] == n_rows
        assert res.fingerprint in FEEDBACK.profiles

    def test_observed_statistics_override_rows(self, q1_setup):
        tables, ctx, frame, n_rows, _ = q1_setup
        FEEDBACK.clear()
        with tracing():
            res = ctx.compile(frame, target="local", cache=PlanCache())
            res(ctx.sources())
        base = ctx.catalog().stats
        obs = FEEDBACK.observed_statistics(base)
        assert obs.table("lineitem").rows == n_rows
        # NDV knowledge survives the row override
        base_t, obs_t = base.table("lineitem"), obs.table("lineitem")
        assert dict(obs_t.ndv).keys() == dict(base_t.ndv).keys()

    def test_exec_calibration_updates(self, q1_setup):
        tables, ctx, frame, _, _ = q1_setup
        n_before = EXEC_CALIBRATION.n
        with tracing():
            res = ctx.compile(frame, target="local", cache=PlanCache())
            res(ctx.sources())
        assert EXEC_CALIBRATION.n == n_before + 1
        assert EXEC_CALIBRATION.seconds(res.profile.est_cost) is not None

    def test_plans_over_threshold(self):
        from repro.obs import OpObservation, RuntimeProfile

        cat = FeedbackCatalog()
        obs = OpObservation(key="k", opcode="vec.MaskSelect", program="p",
                            register="v1", occurrences=1, rows_in=100,
                            rows_out=90, est_rows=10.0)
        cat.record(RuntimeProfile(target="local", program_name="p",
                                  fingerprint="fp1", wall_s=0.1,
                                  observations=(obs,)))
        flagged = cat.plans_over_threshold(threshold=1.0)
        assert flagged == [("fp1", obs.rel_miss)]
        assert cat.plans_over_threshold(threshold=100.0) == []

    def test_replan_with_observed_stats_shifts_estimates(self, q1_setup):
        """The loop closes: a re-compile under observed statistics produces
        estimates that match the measured cardinalities better."""
        tables, ctx, frame, n_rows, _ = q1_setup
        FEEDBACK.clear()
        with tracing():
            res = ctx.compile(frame, target="local", cache=PlanCache())
            res(ctx.sources())
        scan = next(o for o in res.profile.observations
                    if o.opcode == "vec.ScanVec")
        miss_before = abs(scan.rel_miss)

        catalog = ctx.catalog()
        catalog.stats = FEEDBACK.observed_statistics(catalog.stats)
        with tracing():
            res2 = cvm_compile(frame.program(), target="local",
                               catalog=catalog, cache=PlanCache())
            res2(ctx.sources())
        scan2 = next(o for o in res2.profile.observations
                     if o.opcode == "vec.ScanVec")
        assert abs(scan2.rel_miss) <= miss_before
        assert scan2.rows_out == n_rows


# ---------------------------------------------------------------------------
# calibration dataclass sanity (EXEC_CALIBRATION is a separate instance)
# ---------------------------------------------------------------------------


def test_exec_calibration_is_not_compile_calibration():
    from repro.compiler.cost import CALIBRATION

    assert EXEC_CALIBRATION is not CALIBRATION
    assert isinstance(EXEC_CALIBRATION, CostCalibration)
