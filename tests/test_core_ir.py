"""Unit tests for the CVM IR language: types, programs, verification."""

import pytest

from repro.core import Builder, Program, Register, VerificationError, verify, subprogram
from repro.core.expr import AggSpec, col, const
from repro.core.types import (
    BAG, SEQ, SET,
    Atom, Bag, BOOL, CollectionType, F32, I32, I64, ItemType, KDSeq, Seq, Set_,
    Single, Tensor, TupleType, Vec, relation,
)


# ---------------------------------------------------------------------------
# Type grammar
# ---------------------------------------------------------------------------

class TestTypes:
    def test_atom_domains(self):
        assert Atom("f32").np_dtype == "float32"
        with pytest.raises(TypeError):
            Atom("complex128")

    def test_recursive_grammar(self):
        # item := atom | tuple of items | collection of items
        nested = Bag(TupleType.of(a=Bag(TupleType.of(b=F32))))  # NF² relation
        assert nested.item.field("a").item.field("b") == F32

    def test_tuple_duplicate_fields_rejected(self):
        with pytest.raises(TypeError):
            TupleType((("x", F32), ("x", I32)))

    def test_tuple_projection(self):
        t = TupleType.of(a=F32, b=I32, c=BOOL)
        assert t.project(["c", "a"]).names == ("c", "a")

    def test_lex_fields_physical_order(self):
        t = TupleType.of(z=F32, a=I32)
        assert [n for n, _ in t.lex_fields] == ["a", "z"]

    def test_table1_examples(self):
        # RA relation / LA matrix / CSR / row-store — all in one grammar
        ra = relation(SET, a=F32, b=I32)
        assert ra.kind is SET and ra.schema.names == ("a", "b")
        matrix = KDSeq(Atom("num"), (64, 32))
        assert matrix.attr("shape") == (64, 32)
        csr = Single(TupleType.of(A=Vec(F32), I=Vec(I32), O=Vec(I32)))
        assert csr.item.field("A").kind.name == "Vec"
        rowstore = Vec(TupleType.of(v1=F32, v2=I32), max_count=1024)
        assert rowstore.attr("max_count") == 1024

    def test_type_equality_hashable(self):
        a = Bag(TupleType.of(x=F32))
        b = Bag(TupleType.of(x=F32))
        assert a == b and hash(a) == hash(b)
        assert a != Bag(TupleType.of(x=F64())) if callable(F32) else True  # noqa

    def test_tensor(self):
        t = Tensor(F32, (8, 128))
        from repro.core.types import tensor_shape, tensor_dtype
        assert tensor_shape(t) == (8, 128)
        assert tensor_dtype(t) == F32

    def test_render(self):
        t = Bag(TupleType.of(x=F32))
        assert "Bag" in t.render() and "x: f32" in t.render()


F64 = Atom("f64")

LINEITEM = TupleType.of(
    l_quantity=F32, l_eprice=F32, l_disc=F32, l_shipdate=Atom("date"),
)


def tpch_q6_seq() -> Program:
    """Paper Algorithm 1: the sequential Q6 program."""
    b = Builder("Tpch6Seq")
    li = b.input("lineitem", Bag(LINEITEM))
    pred = (
        col("l_shipdate").between(8766, 9131)
        & col("l_disc").between(0.05, 0.07)
        & (col("l_quantity") < 24.0)
    )
    filtered = b.emit1("rel.Select", [li], {"pred": pred})
    projected = b.emit1(
        "rel.ExProj", [filtered], {"exprs": (("x", col("l_eprice") * col("l_disc")),)}
    )
    result = b.emit1(
        "rel.Aggr", [projected], {"aggs": (AggSpec("sum", col("x"), "revenue"),)}
    )
    return b.finish(result)


# ---------------------------------------------------------------------------
# Programs + verifier
# ---------------------------------------------------------------------------

class TestProgram:
    def test_build_and_verify_q6(self):
        p = tpch_q6_seq()
        verify(p)
        assert [i.opcode for i in p.body] == ["rel.Select", "rel.ExProj", "rel.Aggr"]
        # typing: result is Single⟨revenue: f32⟩
        assert p.results[0].type.kind.name == "Single"
        assert p.results[0].type.item.names == ("revenue",)

    def test_ssa_double_assign_rejected(self):
        p = tpch_q6_seq()
        # duplicate the first instruction => double assignment
        bad = p.with_body(list(p.body) + [p.body[0]])
        with pytest.raises(VerificationError, match="assigned twice"):
            verify(bad)

    def test_use_before_def_rejected(self):
        p = tpch_q6_seq()
        bad = p.with_body(list(p.body[::-1]))
        with pytest.raises(VerificationError):
            verify(bad)

    def test_wrong_output_type_rejected(self):
        p = tpch_q6_seq()
        ins0 = p.body[0]
        wrong = ins0.with_outputs([Register(ins0.outputs[0].name, Bag(TupleType.of(zz=F32)))])
        # fix uses so the only error is the typing rule
        with pytest.raises(VerificationError):
            verify(p.with_body([wrong] + list(p.body[1:])))

    def test_rename_all_preserves_verification(self):
        p = tpch_q6_seq()
        q = p.rename_all("_copy")
        verify(q)
        assert all(r.name.endswith("_copy") for r in q.inputs)
        assert q.results[0].name.endswith("_copy")

    def test_higher_order_nested_verify(self):
        inner = tpch_q6_seq()
        b = Builder("outer")
        li = b.input("lineitem", Bag(LINEITEM))
        shards = b.emit1("cf.Split", [li], {"n": 4})
        outs = b.emit("cf.ConcurrentExecute", [shards], {"P": inner})
        merged = b.emit1("cf.Merge", [outs[0]])
        p = b.finish(merged)
        verify(p)
        # walk() visits nested programs
        assert any(q.name == "Tpch6Seq" for q in p.walk())

    def test_concurrent_execute_type_mismatch_rejected(self):
        inner = tpch_q6_seq()
        b = Builder("outer")
        li = b.input("lineitem", Bag(TupleType.of(wrong=F32)))
        shards = b.emit1("cf.Split", [li], {"n": 4})
        with pytest.raises(Exception):
            b.emit("cf.ConcurrentExecute", [shards], {"P": inner})

    def test_loop_requires_type_preserving_body(self):
        t = Tensor(F32, (4, 4))
        body = subprogram("step", [("x", t)], lambda b, rs: [
            b.emit1("la.Ewise", [rs[0]], {"op": "add"}, out_type=t)
        ])
        b = Builder("looped")
        x = b.input("x", t)
        (y,) = b.emit("cf.Loop", [x], {"n": 3, "P": body})
        p = b.finish(y)
        verify(p)

    def test_render_roundtrip_contains_structure(self):
        p = tpch_q6_seq()
        s = p.render()
        assert "program Tpch6Seq" in s and "rel.Aggr" in s and "Return" in s

    def test_unknown_opcode_tolerated_then_rejected(self):
        b = Builder("u")
        x = b.input("x", Bag(LINEITEM))
        out = b.fresh(Bag(LINEITEM))
        from repro.core.program import Instruction
        b.append(Instruction("exotic.Op", (x,), (out,)))
        p = b.finish(out)
        verify(p)  # unknown ops tolerated by default (paper: "leave it as is")
        with pytest.raises(VerificationError):
            verify(p, allow_unknown_ops=False)


class TestExpr:
    def test_inference(self):
        s = LINEITEM
        assert (col("l_quantity") < 24.0).infer(s) == BOOL
        assert (col("l_eprice") * col("l_disc")).infer(s) == F32
        assert (col("l_eprice") + 1).infer(s) == F32

    def test_bad_logic_rejected(self):
        with pytest.raises(TypeError):
            (col("l_eprice") & col("l_disc")).infer(LINEITEM)

    def test_evaluate_numpy(self):
        import numpy as np
        from repro.core.expr import evaluate
        cols = {"l_eprice": np.array([1.0, 2.0]), "l_disc": np.array([0.5, 0.25])}
        out = evaluate(col("l_eprice") * col("l_disc"), cols, np)
        assert out.tolist() == [0.5, 0.5]

    def test_agg_spec_decomposition(self):
        a = AggSpec("count", col("l_disc"), "n")
        assert a.combine_fn == "sum"
        with pytest.raises(ValueError):
            AggSpec("avg", col("l_disc"), "m")
