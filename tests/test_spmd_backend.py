"""SPMD backend: shard_map lowering of parallelized CVM programs.

Runs in a subprocess-configured 8-device host platform (set via conftest?
No — these tests spawn their own subprocess so the main process keeps one
device; jax locks device count at first init).
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.launch.hermetic import subprocess_env

ROOT = Path(__file__).resolve().parents[1]

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax

    from repro.backends.spmd import SpmdBackend
    from repro.core.passes import Parallelize
    from repro.core.passes.lower_vec import Catalog, LowerRelToVec
    from repro.core.passes.rewriter import PassManager
    from repro.launch.mesh import make_mesh
    from repro.relational import tpch
    from repro.relational.runtime import VecTable

    tables = tpch.generate(sf=0.002, seed=11)
    ctx = tpch.make_context(tables, pad_to=1024)

    mesh = make_mesh((8,), ("workers",))
    results = {}
    for qname in ["q1", "q6", "q12"]:
        frame = tpch.QUERIES[qname](ctx)
        program = frame.program(qname)
        program = Parallelize(n=8).apply(program)
        program = LowerRelToVec(ctx.catalog()).apply(program)
        backend = SpmdBackend(mesh)
        compiled = backend.compile(program)
        ops = compiled.program.opcodes()
        assert "mesh.MeshExecute" in ops, ops
        (out,) = compiled(ctx.sources())
        if isinstance(out, VecTable):
            got = {k: np.asarray(v).tolist() for k, v in out.to_numpy().items()}
        elif isinstance(out, dict):
            got = {k: np.asarray(v).tolist() for k, v in out.items()}
        results[qname] = got
        results[qname + "_ops"] = [o for o in ops if o.startswith("mesh.")]
    print("RESULTS" + json.dumps(results))
""")


@pytest.fixture(scope="module")
def spmd_results():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=900,
        env=subprocess_env(ROOT),
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS")][0]
    return json.loads(line[len("RESULTS"):])


def test_spmd_q6_matches_reference(spmd_results):
    import numpy as np
    from repro.relational import tpch

    tables = tpch.generate(sf=0.002, seed=11)
    want = tpch.REFERENCES["q6"](tables)
    got = spmd_results["q6"]
    np.testing.assert_allclose(got["revenue"], want["revenue"], rtol=2e-4)


def test_spmd_q1_matches_reference(spmd_results):
    import numpy as np
    from repro.relational import tpch

    tables = tpch.generate(sf=0.002, seed=11)
    want = tpch.REFERENCES["q1"](tables)
    got = spmd_results["q1"]
    order_g = np.lexsort([got["l_linestatus"], got["l_returnflag"]])
    order_w = np.lexsort([want["l_linestatus"], want["l_returnflag"]])
    np.testing.assert_allclose(
        np.asarray(got["sum_disc_price"])[order_g],
        want["sum_disc_price"][order_w], rtol=2e-4)
    np.testing.assert_array_equal(
        np.asarray(got["count_order"])[order_g], want["count_order"][order_w])


def test_spmd_q12_matches_reference(spmd_results):
    import numpy as np
    from repro.relational import tpch

    tables = tpch.generate(sf=0.002, seed=11)
    want = tpch.REFERENCES["q12"](tables)
    got = spmd_results["q12"]
    order = np.argsort(got["l_shipmode"])
    np.testing.assert_array_equal(np.asarray(got["high_line_count"])[order],
                                  want["high_line_count"])
    np.testing.assert_array_equal(np.asarray(got["low_line_count"])[order],
                                  want["low_line_count"])


def test_collective_rewrite_applied(spmd_results):
    """The scalar-agg query must lower its combine into a mesh.AllReduce."""
    assert "mesh.AllReduce" in spmd_results["q6_ops"]
