"""End-to-end behaviour tests for the CVM system.

These exercise the paper's central claims as executable assertions:
  1. one frontend program → multiple backends, same answer;
  2. rewrites change IR flavor but never semantics;
  3. the LM trainer's distribution is *planned through* CVM (Alg. 1 → 2);
  4. the planned step trains a real (reduced) model.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.backends.interp import Interpreter
from repro.core import verify
from repro.core.expr import col
from repro.frontends.dataflow import Context, count_, sum_


@pytest.fixture(scope="module")
def sales_ctx():
    rng = np.random.default_rng(1)
    n = 4000
    ctx = Context(pad_to=256)
    ctx.register("sales", {
        "region": rng.integers(0, 5, n).astype(np.int32),
        "amount": rng.gamma(2.0, 50.0, n).astype(np.float32),
        "year": rng.integers(2018, 2026, n).astype(np.int32),
    })
    return ctx


class TestMultiBackendConsistency:
    """Claim 1: same frontend program, every execution strategy agrees."""

    def test_local_vs_parallel_vs_interpreter(self, sales_ctx):
        q = (sales_ctx.table("sales")
             .filter(col("year") >= 2021)
             .group_by("region", max_groups=8)
             .agg(sum_("amount").as_("rev"), count_().as_("n")))
        # abstract machine semantics
        (interp_out,) = Interpreter(sources=sales_ctx.tables).run(q.program())
        # local backend, sequential + parallel
        seq = q.collect()
        par = q.collect(parallel=4)
        for got in (seq, par):
            o1 = np.argsort(got["region"])
            o2 = np.argsort(interp_out["region"])
            np.testing.assert_allclose(np.asarray(got["rev"])[o1],
                                       np.asarray(interp_out["rev"])[o2], rtol=1e-4)
            np.testing.assert_array_equal(np.asarray(got["n"])[o1],
                                          np.asarray(interp_out["n"])[o2])

    def test_flavor_changes_through_pipeline(self, sales_ctx):
        """Programs change flavor rel.* → (cf.* +) vec.* during compilation."""
        q = sales_ctx.table("sales").filter(col("year") > 2020).agg(
            sum_("amount").as_("s"))
        logical = q.program().opcodes()
        physical = sales_ctx.compile(q, parallel=4).program.opcodes()
        assert all(o.startswith("rel.") for o in logical)
        assert any(o.startswith("vec.") for o in physical)
        assert any(o.startswith("cf.") for o in physical)


class TestCvmPlansTheTrainer:
    """Claims 3+4: the LM step is planned by the paper's rewrites."""

    def test_plan_has_alg2_structure(self):
        from repro.configs import get_reduced
        from repro.frontends.tensor import plan_summary, plan_train_program
        from repro.models.api import build_model

        model = build_model(get_reduced("qwen2-1.5b"))
        plan = plan_train_program(model, n_data=16)
        verify(plan)
        s = plan_summary(plan)
        assert s["n_workers"] == 16
        assert len(s["split"]) == 1          # the batch is split (DP)
        assert len(s["broadcast"]) >= 1      # params broadcast into workers
        assert "cf.CombineChunks" in s["combines"]  # gradient pre-aggregation
        assert "tz.Pipeline" in s["inner_ops"]      # data path inside CE

    def test_mesh_rewrite_turns_combine_into_allreduce(self):
        from repro.backends.spmd import LowerToMesh, PushCombineIntoMesh
        from repro.configs import get_reduced
        from repro.frontends.tensor import plan_summary, plan_train_program
        from repro.models.api import build_model

        model = build_model(get_reduced("qwen2-1.5b"))
        plan = plan_train_program(model, n_data=8)
        plan = LowerToMesh(axis="data").apply(plan)
        plan = PushCombineIntoMesh().apply(plan)
        verify(plan)
        s = plan_summary(plan)
        assert "mesh.AllReduce" in s["combines"]  # pre-agg became a collective

    def test_lowered_plan_trains(self):
        from repro.configs import get_reduced
        from repro.frontends.tensor import lower_to_pjit, plan_train_program
        from repro.launch.mesh import make_mesh
        from repro.models.api import build_model
        from repro.train.optimizer import AdamW

        cfg = get_reduced("qwen2-1.5b")
        model = build_model(cfg)
        plan = plan_train_program(model, n_data=1)
        mesh = make_mesh((1, 1), ("data", "model"))
        rng = np.random.default_rng(0)
        b, s = 4, 32
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
            "mask": jnp.ones((b, s), jnp.float32),
        }
        with mesh:
            step, summary = lower_to_pjit(plan, model, mesh, AdamW(lr=3e-3),
                                          batch_shapes=batch)
            params = model.init(jax.random.PRNGKey(0))
            opt_state = AdamW(lr=3e-3).init(params)
            p, o, m0 = step(params, opt_state, batch)
            for _ in range(3):
                p, o, m = step(p, o, batch)
        assert float(m["loss"]) < float(m0["loss"])
        assert summary["n_workers"] == 1
