"""End-to-end TPC-H: frontend → CVM rewriting → JAX backend vs numpy oracle.

These are the paper's own workloads (Figs. 2–4).  Each query is validated
(a) on the abstract interpreter and (b) compiled through the full pipeline
(CSE/DCE → [Parallelize] → rel→vec lowering → fusion → jax.jit) on the
local backend, sequential and parallel.
"""

import numpy as np
import pytest

from repro.backends.interp import Interpreter
from repro.relational import tpch


@pytest.fixture(scope="module")
def tables():
    return tpch.generate(sf=0.002, seed=7)


@pytest.fixture(scope="module")
def ctx(tables):
    return tpch.make_context(tables, pad_to=256)


def _sort_rows(d, keys):
    order = np.lexsort([np.asarray(d[k]) for k in reversed(keys)])
    return {k: np.asarray(v)[order] for k, v in d.items()}


def _assert_result_close(got, want, keys=()):
    if keys:
        got, want = _sort_rows(got, keys), _sort_rows(want, keys)
    assert set(want) <= set(got), f"missing columns: {set(want) - set(got)}"
    for k in want:
        g, w = np.asarray(got[k]), np.asarray(want[k])
        assert g.shape == w.shape, f"{k}: shape {g.shape} vs {w.shape}"
        if np.issubdtype(w.dtype, np.integer):
            np.testing.assert_array_equal(g.astype(np.int64), w.astype(np.int64), err_msg=k)
        else:
            np.testing.assert_allclose(g.astype(np.float64), w, rtol=2e-4, err_msg=k)


GROUP_KEYS = {
    "q1": ("l_returnflag", "l_linestatus"),
    "q4": ("o_orderpriority",),
    "q12": ("l_shipmode",),
}


@pytest.mark.parametrize("qname", sorted(tpch.QUERIES))
class TestTPCH:
    def test_interpreter_matches_reference(self, qname, ctx, tables):
        frame = tpch.QUERIES[qname](ctx)
        program = frame.program(qname)
        (out,) = Interpreter(sources=tables).run(program)
        want = tpch.REFERENCES[qname](tables)
        got = out if isinstance(out, dict) else {"result": out}
        # interpreter returns exact tables; scalars come back as dicts
        got = {k: np.asarray(v) for k, v in got.items()}
        _assert_result_close(got, want, GROUP_KEYS.get(qname, ()))

    def test_compiled_sequential(self, qname, ctx, tables):
        got = tpch.QUERIES[qname](ctx).collect()
        want = tpch.REFERENCES[qname](tables)
        _assert_result_close(got, want, GROUP_KEYS.get(qname, ()))

    def test_compiled_parallel(self, qname, ctx, tables):
        got = tpch.QUERIES[qname](ctx).collect(parallel=4)
        want = tpch.REFERENCES[qname](tables)
        _assert_result_close(got, want, GROUP_KEYS.get(qname, ()))


def test_parallel_rewrite_actually_fires_on_q6(ctx):
    """The compiled parallel plan must contain the Split/CE structure."""
    frame = tpch.QUERIES["q6"](ctx)
    compiled = ctx.compile(frame, parallel=4)
    ops = compiled.program.opcodes()
    assert "cf.Split" in ops and "cf.ConcurrentExecute" in ops
    assert "rel.CombinePartials" in ops


def test_fusion_fires_on_q6(ctx):
    """Sequential Q6 must collapse into the single-pass FusedSelectAgg."""
    frame = tpch.QUERIES["q6"](ctx)
    compiled = ctx.compile(frame, parallel=None)
    ops = compiled.program.opcodes()
    assert "vec.FusedSelectAgg" in ops
    assert "vec.MaskSelect" not in ops and "vec.AggrVec" not in ops
