"""Dictionary encoding: the O(n) sort-free tier on string and sparse keys.

Covers the encode=raw|dict strategy choice end to end:
  * string group-by keys run through every target (interp / local / spmd)
    and agree with the interp oracle, both with ``encode=dict`` forced and
    under the costed search;
  * sparse integer keys whose raw span overflows ``MAX_DIRECT_BUCKETS``
    get a ``vec.DictEncode`` → ``vec.GroupAggDirect`` → ``vec.DictDecode``
    sandwich (decode-late: only surviving keys are decoded);
  * string joins handle duplicate, empty-result, and out-of-dictionary
    probe keys;
  * ``lower_vec.direct_unavailable`` / ``hash_unavailable`` warnings name
    *why* encoding was not applied (no stats vs dictionary over budget vs
    strategy forced raw);
  * packing dictionary ranks lifts the 32-bit composite-key ceiling for
    sorted joins.
"""

import json
import subprocess
import sys
import textwrap
import warnings
from pathlib import Path

import numpy as np
import pytest

from repro.compiler import PlanCache, compile as cvm_compile
from repro.core.expr import col
from repro.core.passes.lower_vec import Catalog
from repro.frontends.dataflow import Context, count_, sum_
from repro.launch.hermetic import subprocess_env

ROOT = Path(__file__).resolve().parents[1]

CITIES = ["athens", "berlin", "cairo", "dakar", "edinburgh", "florence",
          "geneva", "havana"]


def make_city_ctx(n=2048, pad_to=256, seed=11):
    rng = np.random.default_rng(seed)
    ctx = Context(pad_to=pad_to)
    ctx.register("sales", {
        "city": np.array(CITIES, dtype=object)[rng.integers(0, len(CITIES), n)],
        "amount": rng.gamma(2.0, 50.0, n).astype(np.float32),
    })
    return ctx


def city_query(ctx, max_groups=16):
    return (ctx.table("sales")
            .group_by("city", max_groups=max_groups)
            .agg(sum_("amount").as_("rev"), count_().as_("n"))
            .order_by("city"))


def assert_frames_equal(got, want):
    assert set(got) == set(want)
    for k in want:
        g, w = np.asarray(got[k]).ravel(), np.asarray(want[k]).ravel()
        assert g.shape == w.shape, (k, g.shape, w.shape)
        if g.dtype.kind in ("U", "S", "O"):
            np.testing.assert_array_equal(g.astype(str), w.astype(str))
        elif g.dtype.kind == "f" or w.dtype.kind == "f":
            np.testing.assert_allclose(g, w, rtol=1e-4)
        else:
            np.testing.assert_array_equal(g, w)


# ---------------------------------------------------------------------------
# string group-by keys, every target
# ---------------------------------------------------------------------------


class TestStringGroupBy:
    def test_forced_dict_direct_matches_interp(self):
        ctx = make_city_ctx()
        q = city_query(ctx)
        want = ctx.execute(q, target="interp")
        assert np.asarray(want["city"]).dtype.kind in ("U", "S", "O")
        got = ctx.execute(q, target="local",
                          strategy={"groupby": "direct", "encode": "dict"})
        # the boundary decode hands back real strings, not rank codes
        assert np.asarray(got["city"]).dtype.kind in ("U", "S", "O")
        assert_frames_equal(got, want)

    def test_cost_search_picks_dict_direct_on_low_card_strings(self):
        ctx = make_city_ctx()
        q = city_query(ctx)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            res = ctx.compile(q, optimize="cost", cache=PlanCache())
        chosen = dict(res.strategy)
        assert chosen["encode"] == "dict"
        assert chosen["groupby"] == "direct"
        assert "vec.GroupAggDirect" in res.program.opcodes()
        want = ctx.execute(q, target="interp")
        got = ctx.execute(q, target="local", optimize="cost")
        assert_frames_equal(got, want)

    def test_string_predicate_remapped_to_code_space(self):
        """String comparison literals are rewritten into global-code space
        before lowering: eq, range, and absent-literal predicates all agree
        with the interp oracle's raw-string comparison."""
        ctx = make_city_ctx()
        for pred in (col("city").eq("cairo"),
                     col("city") >= "dakar",
                     col("city") < "cairo",
                     col("city").eq("zagreb")):      # not in any table
            q = (ctx.table("sales").filter(pred)
                 .group_by("city", max_groups=16)
                 .agg(count_().as_("n")).order_by("city"))
            want = ctx.execute(q, target="interp")
            got = ctx.execute(q, target="local",
                              strategy={"groupby": "direct", "encode": "dict"})
            assert_frames_equal(got, want)


# ---------------------------------------------------------------------------
# sparse integer keys: the DictEncode sandwich
# ---------------------------------------------------------------------------


def make_sparse_ctx(n=4096, ndv=300, pad_to=512, seed=23):
    rng = np.random.default_rng(seed)
    # ~1.5e9 raw span (int32-safe) but only `ndv` distinct values: far over
    # MAX_DIRECT_BUCKETS raw, tiny as dictionary ranks
    domain = rng.integers(0, 1_500_000_000, ndv).astype(np.int32)
    ctx = Context(pad_to=pad_to)
    ctx.register("t", {
        "k": domain[rng.integers(0, ndv, n)],
        "v": rng.normal(size=n).astype(np.float32),
    })
    return ctx


def sparse_query(ctx, max_groups=512):
    return (ctx.table("t").group_by("k", max_groups=max_groups)
            .agg(sum_("v").as_("s"), count_().as_("n")).order_by("k"))


class TestSparseIntKeys:
    def test_dict_encode_sandwich_emitted(self):
        ctx = make_sparse_ctx()
        q = sparse_query(ctx)
        res = ctx.compile(q, strategy={"groupby": "direct", "encode": "dict"},
                          cache=PlanCache())
        ops = res.program.opcodes()
        assert "vec.DictEncode" in ops
        assert "vec.GroupAggDirect" in ops
        assert "vec.DictDecode" in ops
        body = [i.opcode for i in res.program.body]
        # decode-late: the decode sits after the aggregation, on the
        # compacted groups, never on the full input
        assert body.index("vec.DictDecode") > body.index("vec.GroupAggDirect")
        want = ctx.execute(q, target="interp")
        (out,) = res(ctx.sources())
        from repro.frontends.dataflow import _to_numpy
        assert_frames_equal(_to_numpy(out), want)

    def test_forced_raw_warns_and_degrades_to_sorted(self):
        ctx = make_sparse_ctx()
        q = sparse_query(ctx)
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            res = ctx.compile(q, strategy={"groupby": "direct",
                                           "encode": "raw"},
                              cache=PlanCache())
        ops = res.program.opcodes()
        assert "vec.GroupAggDirect" not in ops
        assert "vec.GroupAggSorted" in ops
        msgs = [str(w.message) for w in caught
                if "direct_unavailable" in str(w.message)]
        assert msgs, "downgrade must be loud"
        assert any("strategy forced encode=raw" in m for m in msgs)
        got = ctx.execute(q, target="local",
                          strategy={"groupby": "direct", "encode": "raw"})
        assert_frames_equal(got, ctx.execute(q, target="interp"))

    def test_cost_search_picks_dict_on_sparse_keys(self):
        ctx = make_sparse_ctx()
        q = sparse_query(ctx)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            res = ctx.compile(q, optimize="cost", cache=PlanCache())
        assert dict(res.strategy)["encode"] == "dict"
        assert "vec.GroupAggDirect" in res.program.opcodes()


# ---------------------------------------------------------------------------
# string joins: duplicates, empty results, out-of-dictionary probes
# ---------------------------------------------------------------------------


def make_join_ctx(n_probe=2048, n_build=64, pad_to=256, seed=5):
    rng = np.random.default_rng(seed)
    build_skus = np.array([f"sku-{i:04d}" for i in range(n_build)],
                          dtype=object)
    # probe draws from the build skus *plus* skus that exist nowhere in the
    # build table (out-of-dictionary for the build side), with duplicates
    extra = np.array([f"xsku-{i:04d}" for i in range(16)], dtype=object)
    pool = np.concatenate([build_skus, extra])
    ctx = Context(pad_to=pad_to)
    ctx.register("orders", {
        "sku": pool[rng.integers(0, len(pool), n_probe)],
        "qty": rng.integers(1, 10, n_probe).astype(np.int32),
    })
    ctx.register("parts", {
        "psku": build_skus,
        "price": rng.gamma(2.0, 10.0, n_build).astype(np.float32),
    })
    return ctx


class TestStringJoin:
    def _join_query(self, ctx):
        return (ctx.table("orders")
                .join(ctx.table("parts"), left_on=("sku",),
                      right_on=("psku",))
                .group_by("sku", max_groups=128)
                .agg(sum_("qty").as_("q"), count_().as_("n"))
                .order_by("sku"))

    @pytest.mark.parametrize("strategy", [
        {"join": "hash", "encode": "dict"},
        {"join": "sorted", "encode": "dict"},
        None,  # costed
    ])
    def test_join_with_out_of_dictionary_probes(self, strategy):
        ctx = make_join_ctx()
        q = self._join_query(ctx)
        want = ctx.execute(q, target="interp")
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            got = ctx.execute(
                q, target="local", strategy=strategy,
                optimize=None if strategy else "cost")
        # the unmatched xsku-* probes must have been dropped, not aliased
        assert not any(str(s).startswith("xsku") for s in got["sku"])
        assert_frames_equal(got, want)

    def test_empty_join_result(self):
        rng = np.random.default_rng(2)
        ctx = Context(pad_to=64)
        ctx.register("l", {"k": np.array(["a", "b", "c", "d"] * 8,
                                         dtype=object),
                           "x": rng.normal(size=32).astype(np.float32)})
        ctx.register("r", {"k2": np.array(["w", "y", "z"], dtype=object),
                           "y": np.ones(3, np.float32)})
        q = (ctx.table("l").join(ctx.table("r"), left_on=("k",),
                                 right_on=("k2",))
             .group_by("k", max_groups=8).agg(count_().as_("n")))
        want = ctx.execute(q, target="interp")
        got = ctx.execute(q, target="local",
                          strategy={"join": "hash", "encode": "dict"})
        assert len(np.asarray(got["n"]).ravel()) == 0
        assert_frames_equal(got, want)


# ---------------------------------------------------------------------------
# warning reasons: WHY was encoding not applied
# ---------------------------------------------------------------------------


class TestWarningReasons:
    def _warn_msgs(self, caught, tag):
        return [str(w.message) for w in caught if tag in str(w.message)]

    def test_no_stats_reason(self):
        ctx = make_sparse_ctx()
        program = sparse_query(ctx).program()
        bare = Catalog(capacities={"t": ctx.capacity("t")})  # no statistics
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            cvm_compile(program, target="local", catalog=bare,
                        strategy={"groupby": "direct", "encode": "dict"},
                        cache=PlanCache())
        msgs = self._warn_msgs(caught, "direct_unavailable")
        assert any("no catalog statistics" in m for m in msgs), msgs

    def test_dictionary_over_budget_reason(self):
        # two sparse key columns with ~2048 ranks each: the rank *product*
        # (~4.2M) overflows MAX_DIRECT_BUCKETS even as dictionary ranks
        rng = np.random.default_rng(9)
        n, card = 4096, 2048
        d1 = rng.integers(0, 1_000_000_000, card).astype(np.int32)
        d2 = rng.integers(0, 1_000_000_000, card).astype(np.int32)
        ctx = Context(pad_to=512)
        ctx.register("t", {
            "a": d1[rng.integers(0, card, n)],
            "b": d2[rng.integers(0, card, n)],
            "v": rng.normal(size=n).astype(np.float32),
        })
        q = (ctx.table("t").group_by("a", "b", max_groups=4096)
             .agg(sum_("v").as_("s")))
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            ctx.compile(q, strategy={"groupby": "direct", "encode": "dict"},
                        cache=PlanCache())
        msgs = self._warn_msgs(caught, "direct_unavailable")
        assert any("dictionary over budget" in m for m in msgs), msgs

    def test_forced_raw_reason(self):
        ctx = make_sparse_ctx()
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            ctx.compile(sparse_query(ctx),
                        strategy={"groupby": "direct", "encode": "raw"},
                        cache=PlanCache())
        msgs = self._warn_msgs(caught, "direct_unavailable")
        assert any("strategy forced encode=raw" in m for m in msgs), msgs


# ---------------------------------------------------------------------------
# the 32-bit composite packing ceiling, lifted by packing ranks
# ---------------------------------------------------------------------------


class TestPackingCeilingLift:
    def test_sorted_composite_join_packs_ranks(self):
        rng = np.random.default_rng(17)
        n, card = 2048, 64
        # each column spans ~4.2M raw: the raw product (~1.8e13) is far
        # over the 2^31 packing ceiling; the rank product is 64×64 = 4096
        d1 = (rng.permutation(200_000)[:card] * 21_001).astype(np.int32)
        d2 = (rng.permutation(200_000)[:card] * 21_017).astype(np.int32)
        idx = rng.integers(0, card, n)
        ctx = Context(pad_to=256)
        ctx.register("l", {
            "a": d1[idx], "b": d2[idx],
            "x": rng.normal(size=n).astype(np.float32),
        })
        pairs = rng.permutation(card)
        ctx.register("r", {
            "a2": d1[pairs], "b2": d2[pairs],
            "y": rng.normal(size=card).astype(np.float32),
        })
        q = (ctx.table("l")
             .join(ctx.table("r"), left_on=("a", "b"),
                   right_on=("a2", "b2"))
             .group_by("a", max_groups=128)
             .agg(sum_("y").as_("sy"), count_().as_("n")).order_by("a"))
        res = ctx.compile(q, strategy={"join": "sorted", "encode": "dict"},
                          cache=PlanCache())
        merge = next(i for i in res.program.body
                     if i.opcode == "vec.MergeJoinSorted")
        domains = merge.param("key_domains")
        assert domains is not None
        nb = 1
        for lo, hi in domains:
            nb *= int(hi) - int(lo) + 1
        assert nb <= card * card  # rank space, not the raw span product
        want = ctx.execute(q, target="interp")
        got = ctx.execute(q, target="local",
                          strategy={"join": "sorted", "encode": "dict"})
        assert_frames_equal(got, want)


# ---------------------------------------------------------------------------
# spmd: string keys through the mesh target (own device fleet, subprocess)
# ---------------------------------------------------------------------------

SPMD_DICT_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import warnings
    import numpy as np

    from tests.test_dict_encoding import make_city_ctx, city_query

    ctx = make_city_ctx(n=2048, pad_to=256)
    q = city_query(ctx)
    want = ctx.execute(q, target="interp")
    out = {"want": {k: np.asarray(v).tolist() for k, v in want.items()}}
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        forced = ctx.execute(q, target="spmd", parallel=8,
                             strategy={"groupby": "direct",
                                       "encode": "dict"})
        costed = ctx.execute(q, target="spmd", parallel=8, optimize="cost")
    out["forced"] = {k: np.asarray(v).tolist() for k, v in forced.items()}
    out["costed"] = {k: np.asarray(v).tolist() for k, v in costed.items()}
    print("RESULTS" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def spmd_dict_results():
    proc = subprocess.run(
        [sys.executable, "-c", SPMD_DICT_SCRIPT],
        capture_output=True, text=True, timeout=900,
        env=subprocess_env(ROOT, extra_pythonpath=[str(ROOT)]),
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS")][0]
    return json.loads(line[len("RESULTS"):])


class TestSpmdStringKeys:
    def test_forced_dict_matches_interp(self, spmd_dict_results):
        want = spmd_dict_results["want"]
        got = spmd_dict_results["forced"]
        assert got["city"] == want["city"]  # decoded strings, ordered
        np.testing.assert_allclose(got["rev"], want["rev"], rtol=1e-4)
        np.testing.assert_array_equal(got["n"], want["n"])

    def test_costed_matches_interp(self, spmd_dict_results):
        want = spmd_dict_results["want"]
        got = spmd_dict_results["costed"]
        assert got["city"] == want["city"]
        np.testing.assert_allclose(got["rev"], want["rev"], rtol=1e-4)
        np.testing.assert_array_equal(got["n"], want["n"])
