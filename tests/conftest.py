"""Shared test configuration.

Provides a tiny deterministic stand-in for ``hypothesis`` when the real
package is unavailable (CI installs it from requirements-dev.txt; the dev
container image does not ship it).  The stub runs each ``@given`` test on a
fixed number of pseudo-random examples — far weaker than real hypothesis
(no shrinking, no failure database), but it keeps the property tests
executable everywhere instead of failing at collection.
"""

from __future__ import annotations

import inspect
import random
import sys
import types

try:  # pragma: no cover - prefer the real thing when present
    import hypothesis  # noqa: F401
except ImportError:
    def _install_stub() -> None:
        mod = types.ModuleType("hypothesis")
        st = types.ModuleType("hypothesis.strategies")

        class Strategy:
            def __init__(self, sample):
                self._sample = sample

            def example_from(self, rnd):
                return self._sample(rnd)

        def integers(min_value=0, max_value=100):
            return Strategy(lambda rnd: rnd.randint(min_value, max_value))

        def floats(min_value=0.0, max_value=1.0, allow_nan=None,
                   allow_infinity=None, width=64):
            return Strategy(lambda rnd: rnd.uniform(min_value, max_value))

        def booleans():
            return Strategy(lambda rnd: rnd.random() < 0.5)

        def sampled_from(seq):
            items = list(seq)
            return Strategy(lambda rnd: rnd.choice(items))

        def just(value):
            return Strategy(lambda rnd: value)

        def composite(fn):
            def call(*args, **kwargs):
                def sample(rnd):
                    def draw(strategy):
                        return strategy.example_from(rnd)

                    return fn(draw, *args, **kwargs)

                return Strategy(sample)

            return call

        def settings(max_examples=20, deadline=None, **_ignored):
            def deco(fn):
                fn._stub_max_examples = max_examples
                return fn

            return deco

        def given(*arg_strategies, **kw_strategies):
            if arg_strategies:
                raise NotImplementedError(
                    "hypothesis stub supports keyword @given arguments only")

            def deco(fn):
                sig = inspect.signature(fn)
                remaining = [p for name, p in sig.parameters.items()
                             if name not in kw_strategies]

                def wrapper(*args, **kwargs):
                    n = (getattr(wrapper, "_stub_max_examples", None)
                         or getattr(fn, "_stub_max_examples", None) or 20)
                    rnd = random.Random(0)
                    for _ in range(n):
                        drawn = {k: s.example_from(rnd)
                                 for k, s in kw_strategies.items()}
                        fn(*args, **{**kwargs, **drawn})

                wrapper.__name__ = fn.__name__
                wrapper.__doc__ = fn.__doc__
                # pytest must not mistake the drawn parameters for fixtures
                wrapper.__signature__ = sig.replace(parameters=remaining)
                return wrapper

            return deco

        st.integers = integers
        st.floats = floats
        st.booleans = booleans
        st.sampled_from = sampled_from
        st.just = just
        st.composite = composite
        mod.given = given
        mod.settings = settings
        mod.strategies = st
        mod.__stub__ = True
        sys.modules["hypothesis"] = mod
        sys.modules["hypothesis.strategies"] = st

    _install_stub()
