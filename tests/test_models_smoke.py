"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + no NaNs; decode-vs-forward consistency for
the cached families.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.models.api import build_model, make_train_step, make_serve_step
from repro.train.optimizer import AdamW


def make_batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "mask": jnp.ones((b, s), jnp.float32),
    }
    if cfg.family == "vlm":
        batch["embeds"] = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)), jnp.float32)
        batch["positions3"] = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (3, b, s))
    elif cfg.family == "encdec":
        batch["frames"] = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)), jnp.float32)
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_loss_finite(self, arch):
        cfg = get_reduced(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = make_batch(cfg)
        loss = jax.jit(model.loss)(params, batch)
        assert loss.shape == ()
        assert np.isfinite(float(loss)), f"{arch}: loss not finite"
        # untrained loss should be near ln(vocab)
        assert float(loss) < 2.5 * np.log(cfg.vocab)

    def test_train_step_improves_loss(self, arch):
        cfg = get_reduced(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(1))
        step, opt = make_train_step(model, AdamW(lr=3e-3))
        opt_state = opt.init(params)
        batch = make_batch(cfg, seed=1)
        jstep = jax.jit(step)
        _, _, m0 = jstep(params, opt_state, batch)
        p, s = params, opt_state
        for _ in range(3):
            p, s, m = jstep(p, s, batch)
        assert np.isfinite(float(m["loss"]))
        assert float(m["loss"]) < float(m0["loss"]), f"{arch}: loss did not drop"
        # params stay finite
        for leaf in jax.tree_util.tree_leaves(p):
            assert np.all(np.isfinite(np.asarray(leaf, dtype=np.float32)))


DECODE_ARCHS = [a for a in ARCH_IDS]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_shapes_and_finiteness(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    if model.decode is None:
        pytest.skip("no decode path")
    params = model.init(jax.random.PRNGKey(2))
    b, cap = 2, 16
    rng = np.random.default_rng(3)

    if cfg.family == "encdec":
        frames = jnp.asarray(rng.normal(size=(b, 8, cfg.d_model)), jnp.float32)
        state = model.prefill(params, {"frames": frames}, cap)
    else:
        state = model.init_state(b, cap)

    serve = jax.jit(make_serve_step(model))
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (b, 1)), jnp.int32)
    for _ in range(3):
        tok, logits, state = serve(params, state, tok)
    assert logits.shape == (b, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))
    assert tok.shape == (b, 1)


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "mixtral-8x7b"])
def test_decode_consistent_with_forward(arch):
    """Prefill+decode logits must match the full forward at each position."""
    from repro.models import lm

    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(4))
    rng = np.random.default_rng(5)
    b, s = 1, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)

    logits_full, _ = lm.forward(params, cfg, tokens=toks)

    # prefill on the first s-1 tokens, then decode token s-1
    logits_pre, cache = lm.prefill(params, cfg, tokens=toks[:, :s - 1], cache_capacity=s)
    np.testing.assert_allclose(np.asarray(logits_pre), np.asarray(logits_full[:, s - 2]),
                               rtol=2e-3, atol=2e-3)
    logits_dec, cache = lm.decode_step(params, cfg, cache, toks[:, s - 1:])
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(logits_full[:, s - 1]),
                               rtol=2e-3, atol=2e-3)


def test_rwkv_decode_consistent_with_forward():
    from repro.models import ssm

    cfg = get_reduced("rwkv6-1.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(6))
    rng = np.random.default_rng(7)
    b, s = 1, 6
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)

    x = params["emb"][toks]
    xf, _ = ssm.rwkv_backbone(params, cfg, x)
    logits_full = xf[:, -1].astype(jnp.float32) @ params["emb"].astype(jnp.float32).T

    state = model.init_state(b, s)
    logits = None
    for i in range(s):
        logits, state = model.decode(params, state, toks[:, i:i + 1])
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_full),
                               rtol=2e-3, atol=2e-3)


def test_mamba2_chunked_matches_stepwise():
    """SSD chunked scan == naive recurrent evaluation."""
    from repro.models import ssm

    key = jax.random.PRNGKey(8)
    d_model, d_inner, n = 32, 64, 8
    p = ssm.init_mamba2(key, d_model, d_inner, n, d_head=16)
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(2, 32, d_model)), jnp.float32)

    y_chunk, (cv, st) = ssm.mamba2_block(p, x, d_inner=d_inner, ssm_state=n,
                                         d_head=16, chunk=8)
    # stepwise: feed one token at a time through the decode path
    state = (jnp.zeros((2, 3, d_inner), jnp.float32),
             jnp.zeros((2, d_inner // 16, n, 16), jnp.float32))
    ys = []
    for t in range(32):
        y, state = ssm.mamba2_decode(p, x[:, t:t + 1], state, d_inner=d_inner,
                                     ssm_state=n, d_head=16)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step),
                               rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(state[1]), rtol=5e-3, atol=5e-3)
