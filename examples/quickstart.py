"""Quickstart: one frontend program, three execution strategies.

The CVM promise (paper §1): write the analysis once in the generic Python
frontend; the compiler rewrites it for each platform.  This script builds a
small analytics query and runs it

  1. sequentially (local JITQ-style backend: one fused XLA pipeline),
  2. parallelized (the Split/ConcurrentExecute/pre-aggregate rewrite),
  3. showing the rewritten IR at each stage.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.expr import col
from repro.frontends.dataflow import Context, avg_, count_, sum_

# -- make a toy sales table ---------------------------------------------------
rng = np.random.default_rng(0)
n = 10_000
ctx = Context(pad_to=256)
ctx.register("sales", {
    "region": rng.integers(0, 8, n).astype(np.int32),
    "amount": rng.gamma(2.0, 50.0, n).astype(np.float32),
    "discount": rng.uniform(0, 0.2, n).astype(np.float32),
    "year": rng.integers(2018, 2026, n).astype(np.int32),
})

# -- one frontend program ------------------------------------------------------
q = (
    ctx.table("sales")
    .filter((col("year") >= 2020) & (col("discount") < 0.15))
    .with_columns(net=col("amount") * (1.0 - col("discount")))
    .group_by("region", max_groups=8)
    .agg(sum_("net").as_("revenue"), avg_("amount").as_("avg_amount"),
         count_().as_("n"))
    .order_by("region")
)

print("== logical CVM program (rel.* flavor) ==")
print(q.program("sales_by_region").render())

# -- 1. sequential local backend ----------------------------------------------
seq = q.collect()
print("\n== sequential result ==")
for i in range(len(seq["region"])):
    print(f"  region {seq['region'][i]}: revenue={seq['revenue'][i]:.0f} "
          f"avg={seq['avg_amount'][i]:.1f} n={seq['n'][i]}")

# -- 2. parallelized (paper Alg. 1 → Alg. 2) ------------------------------------
# ctx.compile routes through the unified driver: one entry point per target,
# declarative lowering path, per-pass instrumentation, structural plan cache.
compiled = ctx.compile(q, parallel=4)
print("\n== parallelized physical program (vec.* flavor, 4 workers) ==")
print(compiled.program.render())
print("\n== per-pass instrumentation ==")
print(compiled.explain())
par = q.collect(parallel=4)
assert np.allclose(np.sort(seq["revenue"]), np.sort(par["revenue"]), rtol=1e-5)
print("\nparallel == sequential ✓")

# the abstract machine itself is a registered target — the oracle agrees
oracle = q.collect(target="interp")
assert np.allclose(np.sort(seq["revenue"]), np.sort(np.asarray(oracle["revenue"])),
                   rtol=1e-5)
print("interp (abstract machine) == sequential ✓")

# recompiling the same frontend program is a structural-plan-cache hit
again = ctx.compile(q, parallel=4)
assert again.cache_hit and again.executable is compiled.executable
print("repeated compile hit the plan cache ✓")

# -- 3. scalar aggregate fuses into the single-pass kernel pipeline -------------
q6ish = (
    ctx.table("sales")
    .filter(col("discount").between(0.05, 0.07))
    .agg(sum_(col("amount") * col("discount")).as_("promo_revenue"))
)
c = ctx.compile(q6ish)
ops = c.program.opcodes()
print(f"\nscalar-agg pipeline ops: {ops}")
assert "vec.FusedSelectAgg" in ops, "fusion should produce the single-pass kernel op"
print("fused select+aggregate pipeline ✓ →", q6ish.collect())
