"""k-means through the LA flavor — the paper's Fig. 2 (right) workload.

Shows the two CVM mechanisms the paper credits for matching hand-written
C++ k-means:
  * plan analysis / fusion: CDist2→ArgMinRow→SegSum/SegCount collapses into
    the fused la.KMeansStep ("run-based aggregation"),
  * the parallelization rewrite: points Split, centroids Broadcast,
    partials CombineChunks.

Run: PYTHONPATH=src python examples/kmeans.py
"""

import time

import numpy as np

from repro.backends.local import LocalBackend
from repro.core import Builder, verify
from repro.core.passes import FuseKMeansStep, Parallelize
from repro.core.types import F32, Tensor

n, d, k, iters = 1 << 15, 8, 16, 5
rng = np.random.default_rng(0)
true_centers = rng.normal(0, 5, (k, d)).astype(np.float32)
X = (true_centers[rng.integers(0, k, n)] + rng.normal(0, 1, (n, d))).astype(np.float32)
C0 = X[rng.choice(n, k, replace=False)]

# -- build the UNFUSED program (what a frontend would emit) --------------------
b = Builder("kmeans_iter")
xr = b.input("X", Tensor(F32, (n, d)))
cr = b.input("C", Tensor(F32, (k, d)))
dist = b.emit1("la.CDist2", [xr, cr])
lab = b.emit1("la.ArgMinRow", [dist])
sums = b.emit1("la.SegSum", [xr, lab], {"k": k})
counts = b.emit1("la.SegCount", [lab], {"k": k})
program = b.finish(sums, counts)
print("== frontend program ==")
print(program.render())

# -- fusion + parallelization rewrites -----------------------------------------
program = FuseKMeansStep().apply(program)
program = Parallelize(n=8, targets={xr.name}).apply(program)
verify(program)
print("\n== after FuseKMeansStep + Parallelize(8) ==")
print(program.render())

compiled = LocalBackend().compile(program)


def step(x, c):
    sums, counts = compiled({}, x, c)
    counts = np.maximum(np.asarray(counts), 1e-9)
    return np.asarray(sums) / counts[:, None]


# -- run ------------------------------------------------------------------------
C = C0.copy()
step(X, C)  # warm-up / compile
t0 = time.time()
for it in range(iters):
    C = step(X, C)
cvm_t = (time.time() - t0) / iters

# numpy "sklearn-style" baseline
def np_step(x, c):
    d2 = ((x[:, None, :] - c[None, :, :]) ** 2).sum(-1)
    labf = np.argmin(d2, axis=1)
    sums = np.zeros((k, d)); np.add.at(sums, labf, x)
    cnt = np.maximum(np.bincount(labf, minlength=k), 1)
    return sums / cnt[:, None]

Cn = C0.copy()
t0 = time.time()
for it in range(iters):
    Cn = np_step(X, Cn)
np_t = (time.time() - t0) / iters

err = np.abs(np.sort(C, axis=0) - np.sort(Cn, axis=0)).max()
print(f"\nCVM-compiled k-means: {cvm_t*1e3:.1f} ms/iter; "
      f"numpy baseline: {np_t*1e3:.1f} ms/iter; speedup ×{np_t/cvm_t:.1f}")
print(f"centroid agreement (sorted) max|Δ| = {err:.2e}")
assert err < 1e-2
