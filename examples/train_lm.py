"""End-to-end training driver: a ~100M-class LM for a few hundred steps.

On the CPU container this defaults to a scaled-down qwen2 variant and 120
steps so it finishes in minutes; pass --full-100m on real hardware for the
~100M-parameter configuration (same code path).  Demonstrates the whole
substrate: deterministic data pipeline, AdamW, microbatching, checkpoint +
resume, straggler accounting.

Run: PYTHONPATH=src python examples/train_lm.py [--full-100m]
"""

import argparse

from repro.launch import train

ap = argparse.ArgumentParser()
ap.add_argument("--full-100m", action="store_true",
                help="~100M params (use on real hardware, not the CPU container)")
ap.add_argument("--steps", type=int, default=120)
args = ap.parse_args()

if args.full_100m:
    # ~100M-parameter qwen2-style config: d_model 768, 12L, vocab 32k
    import repro.configs.qwen2_1_5b as q
    from dataclasses import replace
    cfg100 = replace(q.CONFIG, arch="qwen2-100m", n_layers=12, d_model=768,
                     n_heads=12, n_kv_heads=4, d_ff=3072, vocab=32768,
                     d_head=64, dtype="float32", remat=False)
    q.REDUCED = cfg100  # the driver picks it up via --reduced

losses = train.main([
    "--arch", "qwen2-1.5b", "--reduced",
    "--steps", str(args.steps), "--batch", "8", "--seq", "64",
    "--lr", "3e-3", "--ckpt-every", "40",
    "--ckpt-dir", "artifacts/train_lm_ckpt",
])
print(f"loss trajectory: {losses[0]:.3f} → {losses[len(losses)//2]:.3f} → {losses[-1]:.3f}")
assert losses[-1] < losses[0], "training must reduce loss"
print("end-to-end training ✓ (checkpoints in artifacts/train_lm_ckpt)")
