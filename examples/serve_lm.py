"""End-to-end serving driver: batched requests against a small LM.

Serves a reduced qwen2-style model with wave-batched requests through the
functional KV-cache decode path (the serve_step the dry-run lowers at
32k/500k scale).  This is the "serve a small model with batched requests"
end-to-end deliverable; `launch/serve.py` is the production CLI.

Run: PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch import serve

outputs = serve.main([
    "--arch", "qwen2-1.5b", "--reduced",
    "--requests", "12", "--batch", "4",
    "--prompt-len", "12", "--gen", "12", "--cache-cap", "32",
])
print(f"served {len(outputs)} requests; first output tokens: {outputs[0][:8].tolist()}")

# whisper (enc-dec) serving: prefill encodes audio-frame stubs, decode runs
# the decoder with cross-attention
outputs = serve.main([
    "--arch", "whisper-base", "--reduced",
    "--requests", "4", "--batch", "2",
    "--prompt-len", "8", "--gen", "8", "--cache-cap", "16",
])
print(f"whisper served {len(outputs)} requests ✓")
