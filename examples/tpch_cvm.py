"""TPC-H through CVM — the paper's main workload (Figs. 2–4).

Runs all six implemented queries through the full rewrite pipeline on the
local backend, validates against the numpy references, and prints the
optimized physical plans.

Run: PYTHONPATH=src python examples/tpch_cvm.py [--sf 0.005] [--parallel 4]
"""

import argparse
import time

import numpy as np

from repro.relational import tpch

ap = argparse.ArgumentParser()
ap.add_argument("--sf", type=float, default=0.005)
ap.add_argument("--parallel", type=int, default=None)
args = ap.parse_args()

tables = tpch.generate(sf=args.sf, seed=0)
ctx = tpch.make_context(tables)
print(f"TPC-H sf={args.sf}: lineitem={len(tables['lineitem']['l_orderkey']):,} rows, "
      f"orders={len(tables['orders']['o_orderkey']):,}, part={len(tables['part']['p_partkey']):,}")

for qname in sorted(tpch.QUERIES):
    frame = tpch.QUERIES[qname](ctx)
    compiled = ctx.compile(frame, parallel=args.parallel)
    sources = ctx.sources()
    compiled(sources)  # warm-up (compile)
    t0 = time.time()
    reps = 3
    for _ in range(reps):
        (out,) = compiled(sources)
    dt = (time.time() - t0) / reps * 1e3

    from repro.frontends.dataflow import _to_numpy
    got = _to_numpy(out)
    want = tpch.REFERENCES[qname](tables)
    checks = []
    for kcol in want:
        g = np.sort(np.asarray(got[kcol], dtype=np.float64).ravel())
        w = np.sort(np.asarray(want[kcol], dtype=np.float64).ravel())
        checks.append(np.allclose(g, w, rtol=2e-3))
    status = "✓" if all(checks) else "✗ MISMATCH"
    n_ops = len(compiled.program.opcodes())
    print(f"  {qname:>4}: {dt:7.1f} ms   {n_ops:3d} physical ops   ref {status}")
